"""repro.capacity: deployment specs, routing policies, the multi-replica
cluster simulator, and the minimum-chip ladder planner — unit tests on a
synthetic latency model plus the end-to-end ``Configurator.plan_capacity``
acceptance path."""
import dataclasses

import pytest

from repro.api import Configurator
from repro.capacity import (ClusterSimulator, DeploymentSpec, get_router,
                            iter_ladder, plan_min_chips, sweep_ladder)
from repro.capacity.routing import (LeastOutstandingRouter, RoundRobinRouter,
                                    TenantAffinityRouter, _tenant_slot)
from repro.core.config import CandidateConfig, ParallelismConfig
from repro.serving.scheduler import SchedulerConfig
from repro.serving.sim import ServingSimulator, StepSpec
from repro.workloads import (ArrivalSpec, LengthSpec, SLOSpec, TenantSpec,
                             TraceRequest, TraceSpec, WorkloadTrace,
                             constant_trace, generate_trace)


def _lat(spec: StepSpec) -> float:
    return 1e-3 + 1e-6 * sum(c for c, _ in spec.prefill) \
        + 1e-5 * len(spec.decode)


def _cluster(replicas, routing="round_robin", **sched_kw) -> ClusterSimulator:
    return ClusterSimulator(SchedulerConfig(**sched_kw), _lat,
                            replicas=replicas, routing=routing)


def _bursty_trace(rate=50.0, n=60, seed=7):
    return generate_trace(TraceSpec(
        n_requests=n,
        arrivals=ArrivalSpec(kind="bursty", rate_rps=rate, burst_factor=4.0),
        tenants=(TenantSpec(name="chat", weight=0.7, priority=1,
                            lengths=LengthSpec(kind="lognormal",
                                               isl=256, osl=64)),
                 TenantSpec(name="batch", weight=0.3,
                            lengths=LengthSpec(kind="lognormal",
                                               isl=512, osl=96)))),
        seed=seed)


# ---------------------------------------------------------------------------
# DeploymentSpec
# ---------------------------------------------------------------------------

def test_deployment_spec_chips_and_roundtrip():
    dep = DeploymentSpec(
        candidate=CandidateConfig(
            parallel=ParallelismConfig(tp=2, pp=2), batch_size=32),
        replicas=3)
    assert dep.chips_per_replica == 4
    assert dep.total_chips == 12
    assert dep.describe() == "3x[TP2PP2 b32]"
    assert DeploymentSpec.from_dict(dep.to_dict()) == dep
    with pytest.raises(ValueError, match="replicas"):
        DeploymentSpec(candidate=dep.candidate, replicas=0)


def test_deployment_spec_rejects_dp_candidates():
    """replicas IS the data-parallel axis: a dp>1 candidate would be
    billed for dp engines while the cluster simulator runs one per
    replica, so it is rejected rather than mis-costed."""
    with pytest.raises(ValueError, match="supersedes"):
        DeploymentSpec(
            candidate=CandidateConfig(
                parallel=ParallelismConfig(tp=2, dp=2), batch_size=8),
            replicas=1)


# ---------------------------------------------------------------------------
# routing policies
# ---------------------------------------------------------------------------

def test_get_router_by_name_and_rejection():
    assert isinstance(get_router("round_robin"), RoundRobinRouter)
    assert isinstance(get_router("least_outstanding"),
                      LeastOutstandingRouter)
    assert isinstance(get_router("tenant_affinity"), TenantAffinityRouter)
    with pytest.raises(ValueError, match="routing policy"):
        get_router("random")


def test_round_robin_cycles():
    r = RoundRobinRouter()
    assert [r.select([None] * 3, None, seq) for seq in range(6)] \
        == [0, 1, 2, 0, 1, 2]


def test_least_outstanding_picks_min_with_index_tiebreak():
    @dataclasses.dataclass
    class Stub:
        outstanding: int
    r = LeastOutstandingRouter()
    assert r.select([Stub(4), Stub(1), Stub(2)], None, 0) == 1
    assert r.select([Stub(2), Stub(2), Stub(2)], None, 0) == 0


def test_tenant_affinity_is_stable_and_process_independent():
    # sha256-based, never Python's per-process hash: the slot for a given
    # (tenant, n) pair is a fixed value across runs and machines
    assert _tenant_slot("chat", 4) == _tenant_slot("chat", 4)
    assert _tenant_slot("default", 2) in (0, 1)
    r = TenantAffinityRouter()
    req = TraceRequest(arrival_s=0.0, isl=8, osl=2, tenant="chat")
    assert r.select([None] * 4, req, 0) == _tenant_slot("chat", 4)


# ---------------------------------------------------------------------------
# ClusterSimulator
# ---------------------------------------------------------------------------

def test_single_replica_cluster_matches_single_engine_replay():
    trace = _bursty_trace()
    slo = SLOSpec(ttft_p99_ms=500, tpot_p99_ms=100)
    kw = dict(max_batch=8, max_num_tokens=2048)
    single = ServingSimulator(SchedulerConfig(**kw), _lat).replay(
        trace, slo=slo)
    clus = _cluster(1, **kw).replay(trace, slo=slo)
    assert clus.completed == single.completed
    assert clus.rejected == single.rejected
    assert clus.steps == single.steps
    assert clus.ttft_ms == single.ttft_ms
    assert clus.tpot_ms == single.tpot_ms
    assert clus.slo_attainment == single.slo_attainment
    assert clus.goodput_tok_s == pytest.approx(single.goodput_tok_s)


@pytest.mark.parametrize("routing", ["round_robin", "least_outstanding",
                                     "tenant_affinity"])
def test_cluster_accounting_is_consistent(routing):
    trace = _bursty_trace()
    m = _cluster(3, routing=routing, max_batch=4,
                 max_num_tokens=1024).replay(
        trace, slo=SLOSpec(ttft_p99_ms=2000, tpot_p99_ms=100))
    assert m.replicas == 3 and m.routing == routing
    assert m.completed + m.rejected + m.unfinished == trace.n_requests
    assert sum(r["routed"] for r in m.per_replica) == trace.n_requests
    assert sum(r["completed"] for r in m.per_replica) == m.completed
    assert sum(r["steps"] for r in m.per_replica) == m.steps
    assert m.duration_s == max(r["final_clock_s"] for r in m.per_replica)
    assert 0.0 <= m.slo_attainment <= 1.0
    assert m.goodput_tok_s <= m.throughput_tok_s + 1e-9
    assert set(m.imbalance) == {"routed_max_over_mean", "routed_cv",
                                "tokens_max_over_mean", "tokens_cv"}
    d = m.to_dict()
    assert "per_request" not in d and len(d["per_replica"]) == 3


def test_more_replicas_absorb_a_burst():
    """A closed burst that saturates one engine clears faster — and with
    better tail TTFT — on four."""
    trace = constant_trace(isl=128, osl=32, n_requests=32, rate_rps=1e6)
    m1 = _cluster(1, max_batch=2, max_num_tokens=512).replay(trace)
    m4 = _cluster(4, max_batch=2, max_num_tokens=512).replay(trace)
    assert m1.completed == m4.completed == 32
    assert m4.ttft_ms["p99"] < m1.ttft_ms["p99"]
    assert m4.duration_s < m1.duration_s


def test_tenant_affinity_pins_each_tenant_to_one_replica():
    trace = _bursty_trace()
    m = _cluster(4, routing="tenant_affinity", max_batch=8,
                 max_num_tokens=2048).replay(trace)
    seen = {}
    for tenant, replica, _ttft, _tpot in m.per_request:
        seen.setdefault(tenant, set()).add(replica)
    assert seen and all(len(replicas) == 1 for replicas in seen.values())


def test_least_outstanding_balances_a_skewed_tenant_mix():
    """90% of traffic from one tenant: affinity routing piles it on one
    replica while least-outstanding spreads it."""
    trace = generate_trace(TraceSpec(
        n_requests=80,
        arrivals=ArrivalSpec(kind="poisson", rate_rps=100.0),
        tenants=(TenantSpec(name="whale", weight=0.9),
                 TenantSpec(name="minnow", weight=0.1))), seed=5)
    aff = _cluster(4, routing="tenant_affinity", max_batch=2,
                   max_num_tokens=512).replay(trace)
    lo = _cluster(4, routing="least_outstanding", max_batch=2,
                  max_num_tokens=512).replay(trace)
    assert lo.imbalance["routed_cv"] < aff.imbalance["routed_cv"]


def test_cluster_replay_empty_trace_zeroed_and_finite():
    m = _cluster(2, max_batch=2).replay(WorkloadTrace(requests=()),
                                        slo=SLOSpec())
    assert m.n_requests == m.completed == m.rejected == m.steps == 0
    assert m.ttft_ms == {"p50": 0.0, "p95": 0.0, "p99": 0.0}
    assert m.throughput_tok_s == 0.0 and m.queue_depth_mean == 0.0
    assert m.slo_attainment == 0.0 and m.goodput_tok_s == 0.0


def test_cluster_replay_respects_total_step_budget():
    trace = constant_trace(isl=64, osl=64, n_requests=64, rate_rps=1e6)
    m = _cluster(2, max_batch=1, max_num_tokens=128).replay(
        trace, slo=SLOSpec(), max_steps=10)
    assert m.steps <= 10
    assert m.unfinished > 0
    assert m.completed + m.rejected + m.unfinished == 64
    assert m.truncated is True


def test_cluster_truncated_false_on_full_replay():
    trace = constant_trace(isl=32, osl=8, n_requests=12, rate_rps=50.0)
    m = _cluster(2, max_batch=4, max_num_tokens=64).replay(trace)
    assert m.unfinished == 0
    assert m.truncated is False
    assert m.to_dict()["truncated"] is False


def test_iter_ladder_records_truncation_per_rung():
    """A starved step budget marks evaluated rungs truncated; pruned
    rungs keep the None placeholder."""
    trace = _bursty_trace(rate=200.0, n=80)
    cand = CandidateConfig(parallel=ParallelismConfig(tp=1), batch_size=4)
    cfg = SchedulerConfig(max_batch=4, max_num_tokens=128)

    class _Runner:
        def cluster_simulator(self, dep, routing="round_robin", **kw):
            return ClusterSimulator(cfg, _lat, replicas=dep.replicas,
                                    routing=routing)

    slo = SLOSpec(ttft_p99_ms=1e9, tpot_p99_ms=1e9)
    starved = list(iter_ladder(_Runner(), [cand], trace, slo,
                               ladder=(1,), max_steps=6))
    assert starved[0]["truncated"] is True
    assert starved[0]["metrics"]["truncated"] is True
    full = list(iter_ladder(_Runner(), [cand], trace, slo, ladder=(1, 2)))
    assert full[0]["truncated"] is False
    pruned = [r for r in full if r["pruned"] is not None]
    assert all(r["truncated"] is None for r in pruned)


def test_cluster_rejects_on_per_replica_max_queue():
    trace = constant_trace(isl=32, osl=8, n_requests=24, rate_rps=1e6)
    m = _cluster(2, max_batch=1, max_num_tokens=64, max_queue=2).replay(
        trace, slo=SLOSpec(ttft_p99_ms=1e9, tpot_p99_ms=1e9))
    assert m.rejected > 0
    assert m.slo_attainment == pytest.approx(m.completed / 24)


def test_cluster_validates_inputs():
    with pytest.raises(ValueError, match="replicas"):
        _cluster(0)
    with pytest.raises(ValueError, match="routing policy"):
        _cluster(2, routing="lunar")


# ---------------------------------------------------------------------------
# ladder planner (stub runner: synthetic latency, no PerfDatabase)
# ---------------------------------------------------------------------------

class _StubRunner:
    """Just enough TaskRunner surface for the planner: a
    cluster_simulator factory and a fingerprintable session.db."""

    class _DB:
        def fingerprint(self):
            return {"platform": "stub", "backend": "stub",
                    "grid_hash": "0" * 16}

    class _Session:
        db = None

    def __init__(self):
        self.session = self._Session()
        self.session.db = self._DB()
        self.n_simulated = 0

    def cluster_simulator(self, dep, routing="round_robin",
                          priority_admission=True, max_queue=100_000):
        self.n_simulated += 1
        cfg = SchedulerConfig(max_batch=dep.candidate.batch_size,
                              max_num_tokens=512,
                              priority_admission=priority_admission,
                              max_queue=max_queue)
        tp = dep.candidate.parallel.tp     # bigger engine = faster steps

        def lat(spec):
            return _lat(spec) / tp

        return ClusterSimulator(cfg, lat, replicas=dep.replicas,
                                routing=routing)


def _cand(tp=1, batch=2):
    return CandidateConfig(parallel=ParallelismConfig(tp=tp),
                           batch_size=batch)


# one saturating burst: a single small engine blows the tail SLO, two clear it
_PLANNER_TRACE = constant_trace(isl=128, osl=16, n_requests=24, rate_rps=1e6)
_PLANNER_SLO = SLOSpec(ttft_p99_ms=120, tpot_p99_ms=100)


def test_plan_min_chips_finds_cheapest_attaining_rung():
    runner = _StubRunner()
    plan = plan_min_chips(runner, [_cand()], _PLANNER_TRACE, _PLANNER_SLO,
                          ladder=(1, 2, 4))
    assert plan.attained
    assert plan.total_chips == 2
    assert plan.deployment.replicas == 2
    rungs = {r["replicas"]: r for r in plan.section["rungs"]}
    assert rungs[1]["attains"] is False
    assert rungs[2]["attains"] is True
    # monotone-cost early stop: rung 4 never evaluated (4 chips >= 2)
    assert 4 not in rungs
    assert plan.section["plan"]["total_chips"] == 2
    assert "min-chip deployment" in plan.summary()


def test_ladder_prunes_deployments_at_or_above_attained_cost():
    """Candidates at 1 and 4 chips/replica: the 4-chip engine attains at
    rung 1 (cost 4), so its rung-2 deployment (8 chips) is pruned
    without simulation, while the cheaper 1-chip engine is still
    evaluated at rung 2 — where it attains at cost 2 and becomes the
    plan; rung 4 (cheapest deployment 4 chips >= 2) is never visited."""
    runner = _StubRunner()
    section = sweep_ladder(runner, [_cand(tp=1), _cand(tp=4)],
                           _PLANNER_TRACE, _PLANNER_SLO, ladder=(1, 2, 4))
    recs = section["rungs"]
    by_key = {(r["replicas"], r["candidate_rank"]): r for r in recs}
    assert by_key[(1, 0)]["attains"] is False          # 1 chip: too small
    assert by_key[(1, 1)]["attains"] is True           # 4 chips: attains
    assert by_key[(2, 0)]["attains"] is True           # 2 chips: cheaper win
    assert by_key[(2, 1)]["pruned"] is not None        # 8 chips >= 4
    assert by_key[(2, 1)]["metrics"] is None
    assert (4, 0) not in by_key and (4, 1) not in by_key  # early stop
    assert section["n_pruned"] == 1
    assert section["plan"]["total_chips"] == 2
    # simulations ran only for the non-pruned records
    assert runner.n_simulated == section["n_evaluated"]


def test_plan_without_attaining_rung_reports_none():
    runner = _StubRunner()
    plan = plan_min_chips(runner, [_cand()], _PLANNER_TRACE,
                          SLOSpec(ttft_p99_ms=1e-6, tpot_p99_ms=1e-6),
                          ladder=(1, 2))
    assert not plan.attained
    assert plan.deployment is None and plan.total_chips is None
    assert all(r["attains"] is False for r in plan.section["rungs"])
    assert "no deployment" in plan.summary()


def test_attain_target_changes_the_verdict():
    runner = _StubRunner()
    m = runner.cluster_simulator(
        DeploymentSpec(_cand(), 1)).replay(_PLANNER_TRACE,
                                           slo=_PLANNER_SLO)
    partial = m.slo_attainment
    assert 0.0 < partial < 0.95
    easy = sweep_ladder(runner, [_cand()], _PLANNER_TRACE, _PLANNER_SLO,
                        ladder=(1,), attain_target=partial / 2)
    assert easy["plan"]["attained"] is True


def test_ladder_validation():
    runner = _StubRunner()
    kw = dict(trace=_PLANNER_TRACE, slo=_PLANNER_SLO)
    with pytest.raises(ValueError, match="ascending"):
        list(iter_ladder(runner, [_cand()], ladder=(2, 1), **kw))
    with pytest.raises(ValueError, match="duplicate"):
        list(iter_ladder(runner, [_cand()], ladder=(1, 1), **kw))
    with pytest.raises(ValueError, match="non-empty"):
        list(iter_ladder(runner, [_cand()], ladder=(), **kw))
    with pytest.raises(ValueError, match="routing"):
        list(iter_ladder(runner, [_cand()], ladder=(1,), routing="x", **kw))
    with pytest.raises(ValueError, match="attain_target"):
        list(iter_ladder(runner, [_cand()], ladder=(1,),
                         attain_target=1.5, **kw))
    with pytest.raises(ValueError, match="candidate"):
        list(iter_ladder(runner, [], ladder=(1,), **kw))


# ---------------------------------------------------------------------------
# end-to-end: Configurator.plan_capacity (the acceptance path)
# ---------------------------------------------------------------------------

def _capacity_configurator():
    return (Configurator.for_model("llama3.1-8b")
            .traffic(isl=256, osl=64)
            .sla(ttft_ms=2000, min_tokens_per_s_user=10)
            .cluster(chips=8).backend("repro-jax").dtype("fp8")
            .modes("aggregated"))


_E2E_SLO = SLOSpec(ttft_p99_ms=400, tpot_p99_ms=50)


def test_plan_capacity_min_chip_attains_while_next_cheaper_misses():
    """The acceptance property: the planned deployment attains the SLO
    and every strictly cheaper evaluated rung does not."""
    cfg = _capacity_configurator()
    report = cfg.plan_capacity(_bursty_trace(rate=60.0), _E2E_SLO,
                               ladder=(1, 2, 4), top_k=1)
    cap = report.capacity
    plan = cap["plan"]
    assert plan["attained"] is True
    assert plan["slo_attainment"] >= cap["attain_target"]
    cheaper = [r for r in cap["rungs"]
               if r["pruned"] is None
               and r["total_chips"] < plan["total_chips"]]
    assert cheaper, "the min-chip rung must not be the cheapest evaluated"
    assert all(r["attains"] is False for r in cheaper)
    # section carries the provenance the report consumer audits
    assert cap["trace"]["digest"] == _bursty_trace(rate=60.0).digest()
    assert cap["slo"] == _E2E_SLO.to_dict()
    assert cap["database"]["platform"] == "tpu_v5e"
    assert cap["candidates"][0]["analytical_rank"] == 0
    from repro.api import SCHEMA_VERSION
    assert report.schema_version == SCHEMA_VERSION
    assert "capacity plan" in report.summary()


def test_plan_capacity_is_deterministic_across_sessions():
    trace = _bursty_trace(rate=60.0)
    cap1 = _capacity_configurator().plan_capacity(
        trace, _E2E_SLO, ladder=(1, 2), top_k=2).capacity
    cap2 = _capacity_configurator().plan_capacity(
        trace, _E2E_SLO, ladder=(1, 2), top_k=2).capacity
    assert cap1 == cap2


def test_plan_capacity_reuses_supplied_report():
    cfg = _capacity_configurator()
    report = cfg.search(generate_launch=False)
    n_before = report.n_candidates
    out = cfg.plan_capacity(_bursty_trace(rate=60.0), _E2E_SLO,
                            ladder=(1, 2), report=report)
    assert out is report
    assert report.n_candidates == n_before        # no re-search
    assert report.capacity is not None


def test_plan_capacity_accepts_trace_path_and_slo_dict(tmp_path):
    p = tmp_path / "t.jsonl"
    _bursty_trace(rate=60.0).save(str(p))
    report = _capacity_configurator().plan_capacity(
        str(p), {"ttft_p99_ms": 400.0, "tpot_p99_ms": 50.0}, ladder=(2,))
    assert report.capacity["slo"] == {"ttft_p99_ms": 400.0,
                                      "tpot_p99_ms": 50.0}
