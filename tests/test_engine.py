"""Real-engine integration: continuous batching must equal sequential
single-request generation, across families the engine serves."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import get_config
from repro.serving.engine import Engine, EngineConfig
from repro.serving.request import Request


def _make_requests(cfg, n, rng, osl=6):
    out = []
    for i in range(n):
        isl = int(rng.integers(4, 14))
        prompt = rng.integers(0, cfg.vocab_size, isl).tolist()
        out.append(Request(rid=i, isl=isl, osl=osl,
                           arrival=time.perf_counter(), prompt=prompt))
    return out


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "qwen3-moe-30b-a3b"])
def test_engine_matches_static_generation(arch):
    cfg = get_config(arch).reduced()
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, EngineConfig(max_batch=3, max_seq=48))
    rng = np.random.default_rng(0)
    reqs = _make_requests(cfg, 5, rng)
    for r in reqs:
        eng.add_request(r)
    done = eng.run_until_drained()
    assert len(done) == 5

    for r in reqs[:2]:
        toks = jnp.asarray(np.asarray(r.prompt, np.int32)[None])
        lg, cache = models.prefill(params, cfg, toks, max_len=eng._W)
        cache = dict(cache, pos=jnp.asarray([r.isl], np.int32))
        seq = [int(jnp.argmax(lg[0, -1]))]
        for _ in range(r.osl - 1):
            lg, cache = models.decode_step(
                params, cfg, jnp.asarray([[seq[-1]]]), cache)
            seq.append(int(jnp.argmax(lg[0, -1])))
        assert seq == r.out_tokens, f"slot-batched != static for rid {r.rid}"


def test_engine_queues_beyond_slots():
    cfg = get_config("internlm2-1.8b").reduced()
    params = models.init_params(cfg, jax.random.PRNGKey(1))
    eng = Engine(cfg, params, EngineConfig(max_batch=2, max_seq=48))
    rng = np.random.default_rng(1)
    reqs = _make_requests(cfg, 7, rng, osl=4)
    for r in reqs:
        eng.add_request(r)
    done = eng.run_until_drained()
    assert len(done) == 7
    assert all(len(r.out_tokens) == 4 for r in done)
    assert all(r.ttft is not None and r.ttft >= 0 for r in done)


def test_engine_rejects_unservable_family():
    cfg = get_config("whisper-small").reduced()
    with pytest.raises(ValueError):
        Engine(cfg, {}, EngineConfig())
