"""repro.calibrate — measure → fit → persist → load, end to end.

Covers: the measurement harness over both timers, the log-space fitting
layer (skew recovery, degenerate fallbacks, exponent clamping), lossless
artifact round-trips (including the golden fixture under
tests/fixtures/), the PerfDatabase correction layer + fingerprint
surfacing, the Configurator.with_calibration hook, the accuracy report's
"calibrated MAPE <= uncalibrated MAPE" guarantee, and the calibrate CLI.
"""
import json
import math

import pytest

from repro.calibrate import (CalibrationArtifact, DeterministicTimer,
                             FamilyFit, Sample, WallClockTimer,
                             accuracy_report, fit_families, fit_family,
                             format_accuracy, grid_digest, make_timer,
                             run_calibration)
from repro.calibrate.harness import (DEFAULT_AXES, MEASURED_FAMILIES,
                                     MeasurementHarness, subsample)
from repro.core import operators as ops
from repro.core.cli import main as cli_main
from repro.core.perf_database import PerfDatabase

CREATED = "2026-07-28T00:00:00Z"
GOLDEN = "tests/fixtures/calibration_tpu_v5e_repro-jax.json"

#: tiny axes so wall-clock (interpret-mode) measurement stays cheap
TINY_AXES = {
    "gemm": ((64, 128), (128, 256), (128, 256)),
    "attn_prefill": ((64, 128), (64, 128)),
    "attn_decode": ((1, 2), (128, 256)),
    "moe": ((16, 64),),
    "recurrent": ((64, 128),),
}


@pytest.fixture(scope="module")
def artifact():
    return run_calibration("tpu_v5e", "repro-jax",
                           timer=DeterministicTimer("tpu_v5e"),
                           created_at=CREATED, points_per_axis=2)


# ---------------------------------------------------------------------------
# harness + timers
# ---------------------------------------------------------------------------

def test_harness_covers_every_family(artifact):
    assert set(s.family for s in artifact.samples) == set(MEASURED_FAMILIES)
    assert set(artifact.fits) == set(MEASURED_FAMILIES)
    for s in artifact.samples:
        assert s.predicted_s > 0 and s.measured_s > 0


def test_harness_axes_subsample_matches_database_axes():
    h = MeasurementHarness("tpu_v5e", points_per_axis=2)
    for family in MEASURED_FAMILIES:
        spec = h.spec(family)
        for axis, full in zip(spec.axes, DEFAULT_AXES[family]):
            assert set(axis) <= set(full)
            assert axis[0] == full[0] and axis[-1] == full[-1]


def test_subsample_endpoints_and_bounds():
    axis = (1, 2, 4, 8, 16, 32)
    assert subsample(axis, 99) == axis
    assert subsample(axis, 2) == (1, 32)
    assert len(subsample(axis, 3)) == 3
    assert subsample(axis, 1) == (8,)
    with pytest.raises(ValueError):
        subsample(axis, 0)


def test_deterministic_timer_is_deterministic():
    t1 = DeterministicTimer("tpu_v5e")
    t2 = DeterministicTimer("tpu_v5e")
    op = ops.GEMM(64, 256, 256)
    thunk_calls = []
    v1 = t1.time(op, lambda: thunk_calls.append(1))
    v2 = t2.time(op, lambda: thunk_calls.append(1))
    assert v1 == v2 > 0
    assert not thunk_calls          # the CI timer never runs the kernel


def test_deterministic_run_reproduces_artifact_bit_for_bit(artifact):
    again = run_calibration("tpu_v5e", "repro-jax",
                            timer=DeterministicTimer("tpu_v5e"),
                            created_at=CREATED, points_per_axis=2)
    assert again == artifact
    assert again.digest() == artifact.digest()


def test_wallclock_timer_times_the_real_kernels():
    art = run_calibration(
        "tpu_v5e", "repro-jax", timer=WallClockTimer(reps=1, trials=1),
        created_at=CREATED, points_per_axis=2,
        families=["gemm"], axes_override=TINY_AXES)
    assert all(s.measured_s > 0 for s in art.samples)
    assert art.timer == "wallclock"
    fit = art.fits["gemm"]
    assert fit.mape_calibrated <= fit.mape_uncalibrated


@pytest.mark.slow
def test_wallclock_full_pipeline_all_families():
    """The real measurement path: every family's Pallas kernel executed in
    interpret mode on tiny grids — artifact round-trips and calibration
    improves (or at worst matches) the per-family MAPE."""
    art = run_calibration(
        "tpu_v5e", "repro-jax", timer=WallClockTimer(reps=1, trials=1),
        created_at=CREATED, points_per_axis=2, axes_override=TINY_AXES)
    assert set(art.fits) == set(MEASURED_FAMILIES)
    assert CalibrationArtifact.from_json(art.to_json()) == art
    rep = accuracy_report(art)
    for family, row in rep["families"].items():
        assert math.isfinite(row["mape_calibrated"]), family
        assert row["mape_calibrated"] <= row["mape_uncalibrated"], family


def test_make_timer_factory():
    assert make_timer("deterministic", "tpu_v5e").name == "deterministic"
    assert make_timer("wallclock", "tpu_v5e").name == "wallclock"
    with pytest.raises(ValueError, match="unknown timer"):
        make_timer("sundial", "tpu_v5e")


def test_created_at_is_required_provenance():
    with pytest.raises(ValueError, match="created_at"):
        run_calibration("tpu_v5e", timer=DeterministicTimer("tpu_v5e"))


def test_unknown_family_rejected():
    with pytest.raises(ValueError, match="unknown measurement families"):
        MeasurementHarness("tpu_v5e", families=["warp_drive"])


# ---------------------------------------------------------------------------
# fitting layer
# ---------------------------------------------------------------------------

def _samples(family, pairs):
    return [Sample(family=family, coords=(float(i),), predicted_s=p,
                   measured_s=m) for i, (p, m) in enumerate(pairs)]


def test_fit_recovers_pure_scale():
    pairs = [(p, 1.3 * p) for p in (1e-6, 1e-5, 1e-4, 1e-3)]
    fit = fit_family("gemm", _samples("gemm", pairs))
    assert fit.scale == pytest.approx(1.3, rel=1e-6)
    assert fit.exponent == pytest.approx(1.0, abs=1e-9)
    assert fit.mape_calibrated < 1e-6
    assert fit.r2 == pytest.approx(1.0)


def test_fit_recovers_power_law():
    pairs = [(p, 2.0 * p ** 1.1) for p in (1e-6, 1e-5, 1e-4, 1e-3)]
    fit = fit_family("moe", _samples("moe", pairs))
    assert fit.exponent == pytest.approx(1.1, rel=1e-6)
    assert fit.mape_calibrated < 1e-6


def test_fit_clamps_runaway_exponent():
    pairs = [(p, p ** 3) for p in (1e-3, 1e-2, 1e-1)]
    fit = fit_family("gemm", _samples("gemm", pairs))
    assert fit.exponent == 2.0          # EXPONENT_MAX


def test_fit_degenerate_falls_back_to_scale():
    # two samples: slope unidentifiable by policy -> exponent pinned to 1
    fit = fit_family("recurrent",
                     _samples("recurrent", [(1e-4, 2e-4), (1e-3, 3e-3)]))
    assert fit.exponent == 1.0
    # one predictor value repeated: zero variance -> scale only
    fit = fit_family("comm", _samples("comm", [(1e-4, 2e-4)] * 5))
    assert fit.exponent == 1.0
    assert fit.scale == pytest.approx(2.0, rel=1e-6)


def test_fit_families_groups_and_fit_recovers_timer_skew(artifact):
    # the deterministic timer's skew is exactly what the fit must recover
    for family, fit in artifact.fits.items():
        skew = DeterministicTimer.DEFAULT_SKEW[family]
        assert fit.scale == pytest.approx(skew, rel=0.15)
        assert fit.mape_calibrated <= fit.mape_uncalibrated
        assert math.isfinite(fit.r2) and math.isfinite(fit.residual_std)


def test_fit_empty_family_raises():
    with pytest.raises(ValueError, match="no samples"):
        fit_family("gemm", [])
    assert fit_families([]) == {}


# ---------------------------------------------------------------------------
# artifact: schema + lossless round-trip + golden fixture
# ---------------------------------------------------------------------------

def test_artifact_roundtrip_lossless(artifact):
    blob = artifact.to_json()
    again = CalibrationArtifact.from_json(blob)
    assert again == artifact
    assert again.to_json() == blob
    assert again.corrections() == artifact.corrections()
    assert again.digest() == artifact.digest()


def test_artifact_save_load_lossless(tmp_path, artifact):
    path = artifact.save(str(tmp_path / "cal.json"))
    assert CalibrationArtifact.load(path) == artifact


def test_artifact_rejects_wrong_kind_and_version(artifact):
    d = artifact.to_dict()
    bad_kind = dict(d, kind="search-report")
    with pytest.raises(ValueError, match="not a calibration artifact"):
        CalibrationArtifact.from_dict(bad_kind)
    bad_ver = dict(d, schema_version=99)
    with pytest.raises(ValueError, match="unsupported calibration"):
        CalibrationArtifact.from_dict(bad_ver)


def test_grid_digest_tracks_grid_not_latencies(artifact):
    moved = [Sample(s.family, s.coords, s.predicted_s, s.measured_s * 2)
             for s in artifact.samples]
    assert grid_digest(moved) == artifact.grid_digest
    dropped = artifact.samples[1:]
    assert grid_digest(dropped) != artifact.grid_digest


def test_golden_fixture_loads_and_roundtrips(artifact):
    golden = CalibrationArtifact.load(GOLDEN)
    assert golden.schema_version == 1
    assert (golden.platform, golden.backend) == ("tpu_v5e", "repro-jax")
    with open(GOLDEN) as f:
        raw = json.load(f)
    assert CalibrationArtifact.from_dict(raw).to_dict() == raw
    # the deterministic pipeline still reproduces the committed artifact
    # (modulo the fixture's free-text provenance note)
    assert dict(golden.to_dict(), notes="") \
        == dict(artifact.to_dict(), notes="")


# ---------------------------------------------------------------------------
# PerfDatabase correction layer
# ---------------------------------------------------------------------------

def test_database_applies_family_corrections(artifact):
    plain = PerfDatabase("tpu_v5e", "repro-jax")
    cal = PerfDatabase("tpu_v5e", "repro-jax", calibration=artifact)
    g = ops.GEMM(256, 1024, 1024)
    scale, exponent = artifact.corrections()["gemm"]
    t = plain.op_latency(g)
    assert cal.op_latency(g) == pytest.approx(scale * t ** exponent,
                                              rel=1e-9)
    # decode attention goes through its own family
    a = ops.Attention(phase="decode", batch=8, q_len=1, kv_len=2048,
                      heads=8, kv_heads=2, head_dim=64)
    s2, e2 = artifact.corrections()["attn_decode"]
    t2 = plain.op_latency(a)
    assert cal.op_latency(a) == pytest.approx(s2 * t2 ** e2, rel=1e-9)


def test_apply_calibration_invalidates_memo(artifact):
    db = PerfDatabase("tpu_v5e", "repro-jax")
    g = ops.GEMM(512, 512, 512)
    before = db.op_latency(g)
    db.apply_calibration(artifact)
    after = db.op_latency(g)
    assert after != before          # memoized value did not leak through


def test_apply_calibration_rejects_foreign_silicon(artifact):
    with pytest.raises(ValueError, match="tpu_v5p"):
        PerfDatabase("tpu_v5p", "repro-jax").apply_calibration(artifact)
    with pytest.raises(ValueError, match="vllm"):
        PerfDatabase("tpu_v5e", "vllm").apply_calibration(artifact)


def test_fingerprint_surfaces_calibration(artifact):
    plain = PerfDatabase("tpu_v5e", "repro-jax")
    assert plain.fingerprint()["calibration"] is None
    cal = PerfDatabase("tpu_v5e", "repro-jax", calibration=artifact)
    ident = cal.fingerprint()["calibration"]
    assert ident == artifact.identity()
    assert ident["digest"] == artifact.digest()
    assert ident["created_at"] == CREATED


def test_database_save_load_keeps_calibration(tmp_path, artifact):
    db = PerfDatabase("tpu_v5e", "repro-jax", calibration=artifact)
    g = ops.GEMM(128, 1024, 4096)
    want = db.op_latency(g)
    path = db.save(str(tmp_path / "db.json"))
    again = PerfDatabase.load(path)
    assert again.op_latency(g) == pytest.approx(want, rel=1e-12)
    assert again.fingerprint()["calibration"] == artifact.identity()


def test_load_calibration_from_path(tmp_path, artifact):
    path = artifact.save(str(tmp_path / "cal.json"))
    db = PerfDatabase("tpu_v5e", "repro-jax").load_calibration(path)
    assert db.fingerprint()["calibration"]["digest"] == artifact.digest()


# ---------------------------------------------------------------------------
# accuracy report
# ---------------------------------------------------------------------------

def test_accuracy_report_calibrated_beats_uncalibrated(artifact):
    rep = accuracy_report(artifact)
    assert set(rep["families"]) == set(MEASURED_FAMILIES)
    for row in rep["families"].values():
        assert math.isfinite(row["mape_calibrated"])
        assert row["mape_calibrated"] <= row["mape_uncalibrated"]
    o = rep["overall"]
    assert o["mape_calibrated"] <= o["mape_uncalibrated"]
    assert o["n_samples"] == len(artifact.samples)
    text = format_accuracy(rep)
    assert "overall" in text and artifact.digest() in text


def test_accuracy_report_recomputes_from_samples(artifact):
    # strip the fits: uncorrected predictions must audit as-is
    bare = CalibrationArtifact.from_dict(
        dict(artifact.to_dict(), fits={}))
    rep = accuracy_report(bare)
    for row in rep["families"].values():
        assert row["mape_calibrated"] == row["mape_uncalibrated"]


# ---------------------------------------------------------------------------
# Configurator.with_calibration
# ---------------------------------------------------------------------------

def _configurator():
    from repro.api import Configurator
    return (Configurator.for_model("llama3.1-8b")
            .traffic(isl=256, osl=64)
            .sla(ttft_ms=2000, min_tokens_per_s_user=10)
            .cluster(chips=8).backend("repro-jax")
            .modes("aggregated"))


def test_with_calibration_flows_into_search_report(tmp_path, artifact):
    path = artifact.save(str(tmp_path / "cal.json"))
    report = _configurator().with_calibration(path).search(
        generate_launch=False)
    assert report.fingerprint["calibration"] == artifact.identity()
    plain = _configurator().search(generate_launch=False)
    assert plain.fingerprint["calibration"] is None
    # corrections actually moved the projections
    assert plain.best.tpot_ms != report.best.tpot_ms


def test_with_calibration_validates_target_pair(artifact):
    c = _configurator().cluster(chips=8, platform="tpu_v5p")
    with pytest.raises(ValueError, match="tpu_v5p"):
        c.with_calibration(artifact)


def test_compare_variants_off_the_calibrated_pair_price_uncalibrated(
        artifact):
    """A compare sweep must not abort when a variant steers off the
    calibrated (platform, backend): that variant prices uncalibrated and
    its report says so."""
    comparison = _configurator().with_calibration(artifact).compare(
        [{"isl": 128}, {"backend": "trtllm"}], generate_launch=False)
    calibrated, foreign = comparison.reports
    assert calibrated.fingerprint["calibration"] == artifact.identity()
    assert foreign.fingerprint["backend"] == "trtllm"
    assert foreign.fingerprint["calibration"] is None


def test_op_family_is_the_correction_key(artifact):
    """The mapping the database corrects by is the mapping the harness
    measures and the fit keys by — locked via ops.op_family."""
    reps = {
        "gemm": ops.GEMM(64, 256, 256),
        "attn_prefill": ops.Attention(phase="prefill", batch=1, q_len=64,
                                      kv_len=64, heads=4, kv_heads=2,
                                      head_dim=64),
        "attn_decode": ops.Attention(phase="decode", batch=4, q_len=1,
                                     kv_len=256, heads=4, kv_heads=2,
                                     head_dim=64),
        "moe": ops.MoEOp(tokens=32, d_model=256, d_ff=512, num_experts=4,
                         top_k=1),
        "recurrent": ops.RecurrentOp(kind="rglru", batch=1, seq=64,
                                     width=256),
    }
    assert set(reps) == set(MEASURED_FAMILIES)
    plain = PerfDatabase("tpu_v5e", "repro-jax")
    cal = PerfDatabase("tpu_v5e", "repro-jax", calibration=artifact)
    for family, op in reps.items():
        assert ops.op_family(op) == family
        # every measured family's correction actually lands on its ops
        assert cal.op_latency(op) != plain.op_latency(op), family


# ---------------------------------------------------------------------------
# CLI: calibrate run | report | apply
# ---------------------------------------------------------------------------

def test_cli_calibrate_run_report_apply(tmp_path, capsys, artifact):
    out = str(tmp_path / "cal.json")
    rc = cli_main(["calibrate", "run", "--timer", "deterministic",
                   "--points", "2", "--out", out,
                   "--timestamp", CREATED])
    assert rc == 0
    assert CalibrationArtifact.load(out) == artifact
    capsys.readouterr()

    rc = cli_main(["calibrate", "report", "--artifact", out, "--json"])
    assert rc == 0
    rep = json.loads(capsys.readouterr().out)
    assert rep["overall"]["mape_calibrated"] \
        <= rep["overall"]["mape_uncalibrated"]

    rc = cli_main(["calibrate", "apply", "--artifact", out, "--json"])
    assert rc == 0
    fp = json.loads(capsys.readouterr().out)
    assert fp["calibration"]["digest"] == artifact.digest()


def test_cli_calibrate_apply_with_workload(tmp_path, capsys, artifact):
    out = str(tmp_path / "cal.json")
    artifact.save(out)
    rc = cli_main(["calibrate", "apply", "--artifact", out,
                   "--model", "llama3.1-8b", "--isl", "256", "--osl", "64",
                   "--modes", "aggregated", "--dtype", "fp8",
                   "--ttft", "2000", "--min-speed", "10", "--json"])
    assert rc == 0
    report = json.loads(capsys.readouterr().out)
    assert report["database"]["calibration"]["digest"] == artifact.digest()


def test_cli_calibrate_apply_partial_workload_exits_2(tmp_path, capsys,
                                                      artifact):
    out = str(tmp_path / "cal.json")
    artifact.save(out)
    rc = cli_main(["calibrate", "apply", "--artifact", out,
                   "--model", "llama3.1-8b", "--isl", "256"])  # no --osl
    assert rc == 2
    assert "--model/--isl/--osl" in capsys.readouterr().err


def test_cli_calibrate_bad_artifact_exits_2(tmp_path, capsys):
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"kind": "nonsense"}))
    rc = cli_main(["calibrate", "report", "--artifact", str(bad)])
    assert rc == 2
