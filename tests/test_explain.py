"""Conservation tests for repro.obs.explain — the per-candidate waterfall
must reconcile with the pricing oracles it claims to attribute.

The load-bearing property: for every model in the zoo, under both the
scalar oracle (``InferenceSession.spec_latency_ms``) and the fused batch
kernel (``InferenceSession.price_specs``), the explained candidate's
family buckets + overhead sum back to the exact per-iteration latency the
search priced, to ≤ 1e-9 relative.  A waterfall that doesn't add up is
worse than no waterfall.
"""
import pytest

from repro.calibrate import DeterministicTimer, run_calibration
from repro.configs import list_archs
from repro.core.config import SLA, ClusterSpec, WorkloadDescriptor
from repro.core.perf_database import PerfDatabase
from repro.core.session import InferenceSession
from repro.core.task_runner import TaskRunner
from repro.obs.explain import diff_explanations, explain_candidate

ZOO = tuple(list_archs(True))


def _workload(model, chips=8, modes=("aggregated",)):
    return WorkloadDescriptor(
        model=model, isl=256, osl=64, sla=SLA(),
        cluster=ClusterSpec(n_chips=chips, platform="tpu_v5e"),
        backend="repro-jax", modes=modes, dtype="fp8")


_FIT_CACHE = {}


def _session_and_candidate(model):
    """A warm session plus the first memory-fitting candidate, growing the
    cluster until the big MoE checkpoints fit."""
    if model not in _FIT_CACHE:
        for chips in (8, 64, 256):
            runner = TaskRunner(_workload(model, chips=chips))
            for cand in runner.iter_candidates():
                if runner.session._mem_ok(cand)[0]:
                    _FIT_CACHE[model] = (runner.session, cand)
                    break
            if model in _FIT_CACHE:
                break
        else:
            pytest.fail(f"no candidate fits {model} on ≤256 chips")
    return _FIT_CACHE[model]


def _recorded_atoms(session, cand, mode):
    fn = (session.evaluate_static if mode == "static"
          else session.evaluate_aggregated)
    mem = session._mem_ok(cand)
    _, atoms = session.record_specs(
        lambda: fn(cand, _mem=mem, _plan_only=True))
    return atoms


# ---------------------------------------------------------------------------
# conservation: scalar and batched, across the zoo
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("model", ZOO)
def test_waterfall_conserves_scalar_latency(model):
    session, cand = _session_and_candidate(model)
    atoms = _recorded_atoms(session, cand, "aggregated")
    ref_ms = sum(session.spec_latency_ms(p, s, f) for p, s, f in atoms)
    expl = explain_candidate(session, cand, "aggregated")
    assert expl.total_ms == pytest.approx(ref_ms, rel=1e-9)
    assert sum(ph.n_atoms for ph in expl.phases) == len(atoms)
    # per-phase totals are internally consistent too
    for ph in expl.phases:
        assert ph.total_ms == pytest.approx(
            sum(ph.families.values()) + ph.overhead_ms, rel=1e-12)


@pytest.mark.parametrize("model", ZOO)
def test_waterfall_conserves_batched_latency(model):
    session, cand = _session_and_candidate(model)
    if not session.batch_pricing_ok():
        pytest.skip("architecture prices through the scalar path only")
    atoms = _recorded_atoms(session, cand, "aggregated")
    batched_ms = sum(session.price_specs(atoms))
    expl = explain_candidate(session, cand, "aggregated")
    assert expl.total_ms == pytest.approx(batched_ms, rel=1e-9)


def test_waterfall_conserves_static_mode():
    session, cand = _session_and_candidate("llama3.1-8b")
    atoms = _recorded_atoms(session, cand, "static")
    ref_ms = sum(session.spec_latency_ms(p, s, f) for p, s, f in atoms)
    expl = explain_candidate(session, cand, "static")
    assert expl.mode == "static"
    assert expl.total_ms == pytest.approx(ref_ms, rel=1e-9)


def test_waterfall_conserves_with_calibration():
    """Calibration corrections flow through op_latency, so the explained
    buckets must reconcile against the corrected oracle unchanged."""
    art = run_calibration("tpu_v5e", "repro-jax",
                          timer=DeterministicTimer("tpu_v5e"),
                          created_at="2026-07-28T00:00:00Z",
                          points_per_axis=2)
    db = PerfDatabase("tpu_v5e", "repro-jax", calibration=art)
    w = _workload("llama3.1-8b")
    runner = TaskRunner(w, db=db)
    session = runner.session
    cand = next(c for c in runner.iter_candidates()
                if session._mem_ok(c)[0])
    atoms = _recorded_atoms(session, cand, "aggregated")
    scalar_ms = sum(session.spec_latency_ms(p, s, f) for p, s, f in atoms)
    batched_ms = sum(session.price_specs(atoms))
    expl = explain_candidate(session, cand, "aggregated")
    assert expl.total_ms == pytest.approx(scalar_ms, rel=1e-9)
    assert expl.total_ms == pytest.approx(batched_ms, rel=1e-9)
    # and the calibrated oracle actually differs from the uncalibrated one
    plain = InferenceSession(w)
    plain_ms = sum(plain.spec_latency_ms(p, s, f) for p, s, f in atoms)
    assert plain_ms != pytest.approx(scalar_ms, rel=1e-6)


def test_moe_waterfall_attributes_expert_family():
    session, cand = _session_and_candidate("qwen3-moe-30b-a3b")
    expl = explain_candidate(session, cand, "aggregated")
    assert "moe" in expl.families and expl.families["moe"] > 0
    assert expl.total_ms > 0


# ---------------------------------------------------------------------------
# waterfall shape + diff
# ---------------------------------------------------------------------------

def test_waterfall_phases_and_to_dict():
    session, cand = _session_and_candidate("llama3.1-8b")
    expl = explain_candidate(session, cand, "aggregated")
    assert {ph.phase for ph in expl.phases} <= {"prefill", "mixed", "decode"}
    d = expl.to_dict()
    assert d["model"] == "llama3.1-8b" and d["mode"] == "aggregated"
    assert d["total_ms"] == pytest.approx(expl.total_ms)
    assert sum(p["total_ms"] for p in d["phases"]) == pytest.approx(
        expl.total_ms, rel=1e-12)
    assert "ms/iteration" in expl.summary()


def test_diff_explanations_family_table_and_parallel_changes():
    session, cand = _session_and_candidate("llama3.1-8b")
    runner = TaskRunner(_workload("llama3.1-8b"), session=session)
    other = next(c for c in runner.iter_candidates()
                 if session._mem_ok(c)[0]
                 and c.parallel.tp != cand.parallel.tp
                 and c.batch_size == cand.batch_size)
    a = explain_candidate(session, cand, "aggregated")
    b = explain_candidate(session, other, "aggregated")
    d = diff_explanations(a, b)
    assert d.total_candidate_ms == pytest.approx(a.total_ms)
    assert d.total_baseline_ms == pytest.approx(b.total_ms)
    assert set(d.families) == set(a.families) | set(b.families)
    for fam, row in d.families.items():
        assert row["delta_ms"] == pytest.approx(
            row["candidate_ms"] - row["baseline_ms"], abs=1e-15)
    assert d.parallel_changes["tp"] == (cand.parallel.tp, other.parallel.tp)
    assert "tp=" in d.summary() and " vs " in d.summary()


def test_diff_identical_candidates_has_no_changes():
    session, cand = _session_and_candidate("llama3.1-8b")
    a = explain_candidate(session, cand, "aggregated")
    d = diff_explanations(a, a)
    assert d.parallel_changes == {}
    for row in d.families.values():
        assert row["delta_ms"] == 0.0


# ---------------------------------------------------------------------------
# error surface
# ---------------------------------------------------------------------------

def test_explain_rejects_composite_modes():
    session, cand = _session_and_candidate("llama3.1-8b")
    with pytest.raises(ValueError, match="single-engine modes"):
        explain_candidate(session, cand, "disaggregated")


def test_explain_rejects_non_fitting_candidate():
    runner = TaskRunner(_workload("deepseek-v3", chips=8))
    session = runner.session
    cand = next(c for c in runner.iter_candidates()
                if not session._mem_ok(c)[0])
    with pytest.raises(ValueError, match="does not fit memory"):
        explain_candidate(session, cand, "aggregated")


# ---------------------------------------------------------------------------
# Configurator.explain end-to-end
# ---------------------------------------------------------------------------

def test_configurator_explain_with_baseline():
    from repro.api import Configurator
    cfg = (Configurator.for_model("llama3.1-8b")
           .traffic(isl=256, osl=64)
           .cluster(chips=8, platform="tpu_v5e")
           .backend("repro-jax").dtype("fp8").modes("aggregated"))
    ex = cfg.explain(rank=0, baseline=1)
    assert ex.candidate.total_ms > 0
    assert ex.baseline is not None and ex.diff is not None
    assert ex.diff.total_candidate_ms == pytest.approx(
        ex.candidate.total_ms)
    d = ex.to_dict()
    assert set(d) == {"candidate", "baseline", "diff"}
    # leaders come back fastest-first, so the waterfall explains why
    assert ex.candidate.describe != ex.baseline.describe
