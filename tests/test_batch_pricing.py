"""Vectorized whole-space pricing: the fused batch kernel must agree with
the scalar path element-for-element.

Covers: OpGrid.query_batch vs OpGrid.query (grid hits, edge clamps,
interior points — property-tested), the jnp/jit kernel vs the np kernel,
PerfDatabase.sequence_latency_batch vs per-op sequence_latency across the
architecture zoo (dense / MoE / hybrid / ssm), calibration corrections on
the batch path, the GEMM speed-of-light fallback, and the batched
TaskRunner cursor yielding an identical event stream + frontier as the
scalar loop (encoder-decoder and SoL databases fall back transparently).
"""
import dataclasses
import math

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare environment: deterministic fallback shim
    from _hypothesis_compat import given, settings, st

from repro.api.configurator import Configurator
from repro.core import decompose, jaxenv
from repro.core.config import (CandidateConfig, ParallelismConfig,
                               RuntimeFlags, WorkloadDescriptor,
                               ClusterSpec, SLA)
from repro.core.perf_database import OpGrid, PerfDatabase
from repro.serving.sim import StepSpec
from repro.core.session import InferenceSession
from repro.core.task_runner import SearchProgress, TaskRunner

ZOO = ("llama3.1-8b", "qwen3-moe-30b-a3b", "recurrentgemma-2b", "xlstm-350m")


def _grid():
    axes = [[1, 2, 4, 8, 16, 32], [128, 256, 512, 1024]]
    table = np.empty((6, 4))
    for i, m in enumerate(axes[0]):
        for j, n in enumerate(axes[1]):
            table[i, j] = 1e-6 * m * n + 5e-6
    return OpGrid(axes, table)


# ---------------------------------------------------------------------------
# query_batch vs query
# ---------------------------------------------------------------------------

@given(st.lists(st.tuples(st.floats(0.25, 64), st.floats(64, 2048)),
                min_size=1, max_size=32))
@settings(max_examples=50, deadline=None)
def test_query_batch_matches_scalar(points):
    """Interior, clamped-below and clamped-above points all agree."""
    grid = _grid()
    batch = grid.query_batch(np.array(points, dtype=np.float64))
    for got, c in zip(batch, points):
        assert got == pytest.approx(grid.query(c), rel=1e-12)


def test_query_batch_exact_grid_hits():
    grid = _grid()
    pts = [(m, n) for m in (1, 8, 32) for n in (128, 512, 1024)]
    batch = grid.query_batch(np.array(pts, dtype=np.float64))
    for got, (m, n) in zip(batch, pts):
        i = [1, 2, 4, 8, 16, 32].index(m)
        j = [128, 256, 512, 1024].index(n)
        assert got == pytest.approx(grid.table[i, j], rel=1e-12)


def test_query_batch_edge_clamps():
    grid = _grid()
    below = grid.query_batch(np.array([[0.01, 1.0]]))[0]
    above = grid.query_batch(np.array([[1e9, 1e9]]))[0]
    assert below == pytest.approx(grid.query((0.01, 1.0)), rel=1e-12)
    assert above == pytest.approx(grid.query((1e9, 1e9)), rel=1e-12)
    assert below == pytest.approx(grid.table[0, 0], rel=1e-12)
    assert above == pytest.approx(grid.table[-1, -1], rel=1e-12)


def test_query_batch_single_coord_promotes():
    grid = _grid()
    out = grid.query_batch(np.array([3.0, 300.0]))
    assert out.shape == (1,)
    assert out[0] == pytest.approx(grid.query((3.0, 300.0)), rel=1e-12)


def test_query_batch_jax_matches_np():
    """The jitted jnp kernel agrees with the np kernel (x64 enabled for
    the comparison, restored afterwards — jax config is global)."""
    jax = pytest.importorskip("jax")
    prev = jax.config.read("jax_enable_x64")
    try:
        jaxenv.enable_x64(True)
        grid = _grid()
        rng = np.random.default_rng(0)
        pts = np.stack([rng.uniform(0.25, 64, 64),
                        rng.uniform(64, 2048, 64)], axis=1)
        np.testing.assert_allclose(grid.query_batch_jax(pts),
                                   grid.query_batch(pts), rtol=1e-12)
    finally:
        jax.config.update("jax_enable_x64", prev)


# ---------------------------------------------------------------------------
# sequence_latency_batch vs scalar sequence_latency
# ---------------------------------------------------------------------------

def _specs_for(cfg_name):
    """A small spread of step shapes: pure prefill, pure decode, mixed."""
    return [
        StepSpec(prefill=((256, 0),), decode=()),
        StepSpec(prefill=(), decode=(288,) * 8),
        StepSpec(prefill=((128, 0), (256, 128)), decode=(64, 512, 300)),
        StepSpec(prefill=((31, 7),), decode=(1,)),
    ]


def _pars():
    return [ParallelismConfig(tp=1, pp=1, ep=1),
            ParallelismConfig(tp=4, pp=1, ep=1),
            ParallelismConfig(tp=4, pp=2, ep=2),
            ParallelismConfig(tp=8, pp=1, ep=4)]


@pytest.mark.parametrize("model", ZOO)
def test_sequence_latency_batch_matches_scalar(model):
    from repro.configs import get_config
    cfg = get_config(model)
    db = PerfDatabase("tpu_v5e", "repro-jax")
    items, expected = [], []
    for par in _pars():
        for spec in _specs_for(model):
            op_list = decompose.iteration_ops(cfg, par, spec, dtype="fp8")
            if not op_list:
                continue
            items.append((cfg, par, spec))
            expected.append(db.sequence_latency(op_list))
    batch = decompose.encode_iteration_batch(items, dtype="fp8")
    assert batch is not None and batch.n_items == len(items)
    got = db.sequence_latency_batch(batch)
    np.testing.assert_allclose(got, expected, rtol=1e-9)


def test_sequence_latency_batch_with_calibration():
    """Per-family corrections apply identically on the batch path."""
    from repro.calibrate import DeterministicTimer, run_calibration
    from repro.configs import get_config
    artifact = run_calibration("tpu_v5e", "repro-jax",
                               timer=DeterministicTimer("tpu_v5e"),
                               created_at="2026-08-01T00:00:00Z",
                               points_per_axis=2)
    cfg = get_config("qwen3-moe-30b-a3b")
    db = PerfDatabase("tpu_v5e", "repro-jax", calibration=artifact)
    items, expected = [], []
    for par in _pars():
        spec = StepSpec(prefill=((256, 0),), decode=(64,) * 4)
        items.append((cfg, par, spec))
        expected.append(db.sequence_latency(
            decompose.iteration_ops(cfg, par, spec)))
    got = db.sequence_latency_batch(decompose.encode_iteration_batch(items))
    np.testing.assert_allclose(got, expected, rtol=1e-9)


def test_sequence_latency_batch_gemm_sol_fallback():
    """With the GEMM grid removed, the batch path reproduces the scalar
    speed-of-light fallback (and counts it in stats)."""
    from repro.configs import get_config
    cfg = get_config("llama3.1-8b")
    db = PerfDatabase("tpu_v5e", "repro-jax")
    for key in [k for k in db._grids if k[0] == "gemm"]:
        del db._grids[key]
    par = ParallelismConfig(tp=2, pp=1, ep=1)
    spec = StepSpec(prefill=((256, 0),), decode=(64, 64))
    expected = db.sequence_latency(decompose.iteration_ops(cfg, par, spec))
    before = db.stats.sol_fallbacks
    got = db.sequence_latency_batch(
        decompose.encode_iteration_batch([(cfg, par, spec)]))
    assert got[0] == pytest.approx(expected, rel=1e-9)
    assert db.stats.sol_fallbacks > before


def test_encoder_decoder_returns_none():
    from repro.configs import get_config
    cfg = get_config("whisper-small")
    par = ParallelismConfig(tp=1, pp=1, ep=1)
    spec = StepSpec(prefill=((64, 0),), decode=())
    assert decompose.encode_iteration_batch([(cfg, par, spec)]) is None


# ---------------------------------------------------------------------------
# the batched cursor vs the scalar search loop
# ---------------------------------------------------------------------------

def _workload(model, modes=("static", "aggregated")):
    return WorkloadDescriptor(
        model=model, isl=256, osl=64, sla=SLA(),
        cluster=ClusterSpec(n_chips=8, platform="tpu_v5e"),
        backend="repro-jax", modes=modes, dtype="fp8")


@pytest.mark.parametrize("model", ZOO)
def test_batched_iter_search_matches_scalar(model):
    w = _workload(model)
    runs = {}
    for batched in (False, True):
        runner = TaskRunner(w)
        progress = SearchProgress()
        events = [(cand.describe(), p.mode, p.ttft_ms, p.tpot_ms,
                   p.tokens_per_s_per_chip)
                  for cand, p in runner.iter_search(progress=progress,
                                                    batched=batched)]
        runs[batched] = (events, progress.n_evaluated, progress.n_yielded)
    scalar, batch = runs[False], runs[True]
    assert scalar[1:] == batch[1:]              # n_evaluated / n_yielded
    assert len(scalar[0]) == len(batch[0])
    for (ds, ms, t1, t2, tc), (db_, mb, u1, u2, uc) in zip(scalar[0],
                                                           batch[0]):
        assert (ds, ms) == (db_, mb)            # same candidate, same order
        assert t1 == pytest.approx(u1, rel=1e-9)
        assert t2 == pytest.approx(u2, rel=1e-9)
        assert tc == pytest.approx(uc, rel=1e-9)


def test_batched_search_identical_frontier_and_ranking():
    """Same frontier membership and throughput ranking, batched vs not."""
    def rep(batched):
        return (Configurator.for_model("qwen3-moe-30b-a3b")
                .traffic(isl=256, osl=64)
                .cluster(chips=8, platform="tpu_v5e")
                .modes("aggregated")
                .search(batched=batched, generate_launch=False))
    rs, rb = rep(False), rep(True)
    assert len(rs.projections) == len(rb.projections)
    rank = lambda r: [p.config["describe"] for p in
                      sorted(r.projections,
                             key=lambda p: -p.tokens_per_s_per_chip)]
    assert rank(rs) == rank(rb)
    front = lambda r: sorted(p.config["describe"] for p in r.frontier)
    assert front(rs) == front(rb)
    assert (rs.best is None) == (rb.best is None)
    if rs.best is not None:
        assert rs.best.config["describe"] == rb.best.config["describe"]


def test_batched_early_exit_prices_at_most_one_chunk():
    """Abandoning the stream early skips the untouched chunks."""
    w = _workload("llama3.1-8b", modes=("aggregated",))
    runner = TaskRunner(w)
    progress = SearchProgress()
    it = runner.iter_search(progress=progress, batched=True)
    for _ in range(3):
        next(it)
    it.close()
    assert progress.n_evaluated <= jaxenv.pricing_chunk() + 3


def test_seq_queries_parity_scalar_vs_batched():
    """``DatabaseStats.seq_queries`` counts pricing *demand*, not path
    mechanics: a full search must report the identical count (and memo-hit
    count) whether it priced through the scalar oracle or the fused batch
    kernel — the probe the streaming-search tests calibrate against."""
    counts = {}
    for batched in (False, True):
        runner = TaskRunner(_workload("llama3.1-8b", modes=("aggregated",)))
        n = len(list(runner.iter_search(batched=batched)))
        stats = runner.session.db.stats
        counts[batched] = (n, stats.seq_queries, stats.seq_hits)
        assert stats.seq_queries > 0
    assert counts[False] == counts[True]


def test_seq_queries_early_exit_differential_both_paths():
    """Abandoning a stream early must register as fewer priced sequences
    than a drained one, under both pricing paths."""
    for batched in (False, True):
        full = TaskRunner(_workload("llama3.1-8b", modes=("aggregated",)))
        list(full.iter_search(batched=batched))
        early = TaskRunner(_workload("llama3.1-8b", modes=("aggregated",)))
        it = early.iter_search(batched=batched)
        for _ in range(3):
            next(it)
        it.close()
        assert 0 < early.session.db.stats.seq_queries \
            < full.session.db.stats.seq_queries, f"batched={batched}"


def test_sol_database_falls_back_to_scalar():
    """use_grid=False databases cannot batch: the cursor must transparently
    price through the scalar path and still yield projections."""
    w = _workload("llama3.1-8b", modes=("aggregated",))
    db = PerfDatabase("tpu_v5e", "repro-jax", use_grid=False)
    runner = TaskRunner(w, db=db)
    assert not runner.session.batch_pricing_ok()
    out = list(runner.iter_search(batched=True))
    assert out and all(p.ttft_ms > 0 for _, p in out)


def test_batched_pricing_env_default(monkeypatch):
    monkeypatch.setenv("REPRO_BATCHED_PRICING", "0")
    assert jaxenv.batched_pricing_default() is False
    monkeypatch.setenv("REPRO_BATCHED_PRICING", "1")
    assert jaxenv.batched_pricing_default() is True
    monkeypatch.delenv("REPRO_BATCHED_PRICING")
    assert jaxenv.batched_pricing_default() is True
    monkeypatch.setenv("REPRO_PRICING_CHUNK", "7")
    assert jaxenv.pricing_chunk() == 7


# ---------------------------------------------------------------------------
# memory-model bugfixes the batch path must not inherit
# ---------------------------------------------------------------------------

def test_hybrid_kv_bytes_recurrent_state_shards_with_tp():
    """Recurrent-state bytes follow _rec_ops' w_loc = ceil(lru_width/tp):
    doubling tp must halve the recurrent-only KV footprint (charging the
    full width over-counted by tp× and wrongly pruned hybrid configs)."""
    from repro.configs import get_config
    cfg = get_config("recurrentgemma-2b")
    rec_only = dataclasses.replace(
        cfg, block_pattern=("rec",) * cfg.num_layers)
    b = decompose.kv_bytes_per_chip(
        rec_only, ParallelismConfig(tp=1, pp=1, ep=1), batch=8, seq=4096)
    h = decompose.kv_bytes_per_chip(
        rec_only, ParallelismConfig(tp=2, pp=1, ep=1), batch=8, seq=4096)
    assert h == pytest.approx(b / 2, rel=1e-9)
    # and the whole hybrid footprint strictly shrinks as tp grows
    full_1 = decompose.kv_bytes_per_chip(
        cfg, ParallelismConfig(tp=1, pp=1, ep=1), batch=8, seq=4096)
    full_2 = decompose.kv_bytes_per_chip(
        cfg, ParallelismConfig(tp=2, pp=1, ep=1), batch=8, seq=4096)
    assert full_2 < full_1


def test_resolve_kv_fraction_uses_candidate_max_num_tokens():
    """The generator's activation budget follows the candidate's actual
    RuntimeFlags.max_num_tokens, so the launch artifact agrees with the
    memory model the search applied."""
    from repro.core import generator
    w = _workload("llama3.1-8b", modes=("aggregated",))
    par = ParallelismConfig(tp=1, pp=1, ep=1)
    small = generator.resolve_kv_fraction(w, par, 32, max_num_tokens=4096)
    big = generator.resolve_kv_fraction(w, par, 32, max_num_tokens=16384)
    default = generator.resolve_kv_fraction(w, par, 32)
    assert big > small                    # less free HBM -> larger fraction
    from repro.core.backends.base import get_backend
    assert default == generator.resolve_kv_fraction(
        w, par, 32, max_num_tokens=get_backend(w.backend).default_max_num_tokens)


def test_generated_launch_consistent_with_searched_flags():
    """End to end: a sweep_flags search's launch artifact resolves its KV
    fraction from the winning candidate's max_num_tokens."""
    from repro.core import generator
    rep = (Configurator.for_model("llama3.1-8b")
           .traffic(isl=256, osl=64)
           .cluster(chips=8, platform="tpu_v5e")
           .modes("aggregated")
           .search(sweep_flags=True))
    assert rep.best is not None and rep.launch is not None
    mt = rep.best.config["flags"]["max_num_tokens"]
    assert rep.launch.raw["runtime_flags"]["max_num_tokens"] == mt
    par = ParallelismConfig(**rep.best.config["parallel"])
    want = generator.resolve_kv_fraction(rep.workload, par,
                                         rep.best.batch_size,
                                         max_num_tokens=mt)
    assert rep.launch.raw["runtime_flags"]["kv_cache_mem_fraction"] == want
