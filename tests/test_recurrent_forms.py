"""Algebraic-equivalence tests for the recurrent substrates: mLSTM
parallel == chunkwise == recurrent; RG-LRU associative scan == stepwise;
whisper encoder determinism."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import hybrid, xlstm


def _mlstm_inputs(B=2, S=96, NH=4, dh=32, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (B, S, NH, dh))
    k = jax.random.normal(ks[1], (B, S, NH, dh))
    v = jax.random.normal(ks[2], (B, S, NH, dh))
    li = jax.random.normal(ks[3], (B, S, NH)).astype(jnp.float32)
    lf = jax.nn.log_sigmoid(
        jax.random.normal(ks[4], (B, S, NH)) + 1.0).astype(jnp.float32)
    return q, k, v, li, lf


def test_mlstm_parallel_vs_chunkwise():
    q, k, v, li, lf = _mlstm_inputs()
    par, _, _ = xlstm.mlstm_parallel(q, k, v, li, lf)
    for chunk in (16, 32, 96, 100):
        chk = xlstm.mlstm_chunkwise(q, k, v, li, lf, chunk=chunk)
        np.testing.assert_allclose(np.asarray(par), np.asarray(chk),
                                   atol=1e-4, rtol=1e-4)


def test_mlstm_parallel_vs_recurrent():
    q, k, v, li, lf = _mlstm_inputs(S=40)
    par, _, _ = xlstm.mlstm_parallel(q, k, v, li, lf)
    B, S, NH, dh = q.shape
    state = (jnp.zeros((B, NH, dh, dh)), jnp.zeros((B, NH, dh)),
             jnp.full((B, NH), -1e30))
    outs = []
    for t in range(S):
        h, state = xlstm.mlstm_step(q[:, t], k[:, t], v[:, t],
                                    li[:, t], lf[:, t], state)
        outs.append(h)
    np.testing.assert_allclose(np.asarray(par),
                               np.asarray(jnp.stack(outs, 1)),
                               atol=1e-4, rtol=1e-4)


def test_mlstm_chunkwise_state_handoff():
    """Final chunkwise state must continue exactly into step decoding."""
    q, k, v, li, lf = _mlstm_inputs(S=64)
    hs, (C, n, m) = xlstm.mlstm_chunkwise(q, k, v, li, lf, chunk=16,
                                          return_state=True)
    q2, k2, v2, li2, lf2 = _mlstm_inputs(S=1, seed=7)
    h_step, _ = xlstm.mlstm_step(q2[:, 0], k2[:, 0], v2[:, 0],
                                 li2[:, 0], lf2[:, 0], (C, n, m))
    # reference: full parallel over concatenated sequence
    qq = jnp.concatenate([q, q2], 1)
    kk = jnp.concatenate([k, k2], 1)
    vv = jnp.concatenate([v, v2], 1)
    ll = jnp.concatenate([li, li2], 1)
    ff = jnp.concatenate([lf, lf2], 1)
    ref, _, _ = xlstm.mlstm_parallel(qq, kk, vv, ll, ff)
    np.testing.assert_allclose(np.asarray(h_step), np.asarray(ref[:, -1]),
                               atol=1e-4, rtol=1e-4)


def test_rglru_assoc_scan_vs_steps():
    lp = {
        "w_a": jax.random.normal(jax.random.PRNGKey(0), (16, 16)) * 0.3,
        "b_a": jnp.zeros(16),
        "w_x": jax.random.normal(jax.random.PRNGKey(1), (16, 16)) * 0.3,
        "b_x": jnp.zeros(16),
        "lambda_p": jnp.ones(16),
    }
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 24, 16))
    seq_out, h_last = hybrid.rglru_seq(lp, x, None)
    h = jnp.zeros((2, 16), jnp.float32)
    outs = []
    for t in range(24):
        y, h = hybrid.rglru_step(lp, x[:, t:t + 1], h)
        outs.append(y[:, 0])
    step_out = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(seq_out, np.float32),
                               np.asarray(step_out, np.float32),
                               atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(h), atol=1e-5)


def test_causal_conv_seq_vs_step():
    lp = {"conv_w": jax.random.normal(jax.random.PRNGKey(3), (4, 8)),
          "conv_b": jnp.zeros(8)}
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 10, 8))
    seq = hybrid.causal_conv_seq(lp, x)
    state = jnp.zeros((2, 3, 8))
    outs = []
    for t in range(10):
        y, state = hybrid.causal_conv_step(lp, x[:, t:t + 1], state)
        outs.append(y[:, 0])
    np.testing.assert_allclose(np.asarray(seq),
                               np.asarray(jnp.stack(outs, 1)), atol=1e-5)


def test_blockwise_attention_grad_finite():
    """The remat'd blockwise attention path is differentiable."""
    from repro.models import common as cm
    q = jax.random.normal(jax.random.PRNGKey(5), (1, 64, 4, 32))
    k = jax.random.normal(jax.random.PRNGKey(6), (1, 64, 2, 32))
    v = jax.random.normal(jax.random.PRNGKey(7), (1, 64, 2, 32))

    def f(q):
        q5 = q.reshape(1, 64, 2, 2, 32)
        return cm._blockwise_attention(q5, k, v, True, 0, 0,
                                       bq=16, bk=16).sum()

    g = jax.grad(f)(q)
    assert jnp.isfinite(g).all()
    # and matches plain-path gradient
    def f_plain(q):
        return cm.attention(q, k, v, None, causal=True).sum()
    gp = jax.grad(f_plain)(q)
    np.testing.assert_allclose(np.asarray(g), np.asarray(gp),
                               atol=1e-4, rtol=1e-3)
