"""repro.workloads: trace model, seeded generators, SLO/goodput scoring,
open-loop replay, and priority admission."""
import json
import math

import pytest

from repro.serving.request import Request
from repro.serving.scheduler import ContinuousBatchingScheduler, SchedulerConfig
from repro.serving.sim import ServingSimulator, StepSpec, percentile
from repro.workloads import (ARRIVAL_KINDS, ArrivalSpec, LengthSpec, SLOSpec,
                             TenantSpec, TraceRequest, TraceSpec,
                             WorkloadTrace, constant_trace, generate_trace)


def _lat(spec: StepSpec) -> float:
    return 1e-3 + 1e-6 * sum(c for c, _ in spec.prefill) \
        + 1e-5 * len(spec.decode)


def _sim(**kw) -> ServingSimulator:
    return ServingSimulator(SchedulerConfig(**kw), _lat)


# ---------------------------------------------------------------------------
# trace model
# ---------------------------------------------------------------------------

def test_trace_roundtrip_exact():
    t = WorkloadTrace(requests=(
        TraceRequest(arrival_s=0.0, isl=10, osl=5),
        TraceRequest(arrival_s=0.123456789012345, isl=2048, osl=512,
                     tenant="batch", priority=-1)),
        meta={"note": "hand-built"})
    t2 = WorkloadTrace.from_jsonl(t.to_jsonl())
    assert t2 == t
    assert t2.requests[1].arrival_s == t.requests[1].arrival_s  # float-exact
    assert t2.digest() == t.digest()


def test_trace_validation():
    with pytest.raises(ValueError, match="non-decreasing"):
        WorkloadTrace(requests=(TraceRequest(1.0, 8, 8),
                                TraceRequest(0.5, 8, 8)))
    with pytest.raises(ValueError, match="negative"):
        WorkloadTrace(requests=(TraceRequest(-0.1, 8, 8),))
    with pytest.raises(ValueError, match="isl/osl"):
        WorkloadTrace(requests=(TraceRequest(0.0, 0, 8),))


def test_trace_jsonl_format_rejections():
    with pytest.raises(ValueError, match="header"):
        WorkloadTrace.from_jsonl('{"arrival_s": 0, "isl": 1, "osl": 1}\n')
    with pytest.raises(ValueError, match="schema_version"):
        WorkloadTrace.from_jsonl(
            '{"type": "header", "schema_version": 99}\n')
    with pytest.raises(ValueError, match="declares"):
        WorkloadTrace.from_jsonl(
            '{"type": "header", "schema_version": 1, "n_requests": 5}\n'
            '{"arrival_s": 0.0, "isl": 4, "osl": 4}\n')


def test_trace_describe_and_views():
    t = generate_trace(TraceSpec(
        n_requests=50, arrivals=ArrivalSpec(rate_rps=10.0),
        tenants=(TenantSpec(name="a", weight=1.0),
                 TenantSpec(name="b", weight=1.0))), seed=1)
    d = t.describe()
    assert d["n_requests"] == 50
    assert sum(d["tenants"].values()) == 50
    assert set(d["tenants"]) == {"a", "b"} == set(t.tenants)
    assert d["isl"]["p50"] <= d["isl"]["p95"] <= d["isl"]["max"]
    assert t.mean_isl() >= 1 and t.mean_osl() >= 1
    assert t.arrival_rate_rps() > 0
    assert d["meta"]["generator"]["seed"] == 1


# ---------------------------------------------------------------------------
# generators: determinism + distribution shape
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ARRIVAL_KINDS)
def test_generator_deterministic_and_sorted(kind):
    spec = TraceSpec(n_requests=80,
                     arrivals=ArrivalSpec(kind=kind, rate_rps=5.0))
    a = generate_trace(spec, seed=42)
    b = generate_trace(spec, seed=42)
    assert a == b and a.digest() == b.digest()
    assert generate_trace(spec, seed=43) != a
    arr = [r.arrival_s for r in a.requests]
    assert arr == sorted(arr)
    assert all(x >= 0 for x in arr)
    assert len(arr) == 80


def test_bursty_mean_rate_invariant_to_burst_factor():
    """rate_rps is the *time-weighted mean*: raising burst_factor must
    change burstiness, not offered load."""
    rates = {}
    for bf in (1.5, 4.0, 8.0):
        t = generate_trace(TraceSpec(
            n_requests=2000,
            arrivals=ArrivalSpec(kind="bursty", rate_rps=4.0,
                                 burst_factor=bf)), seed=17)
        rates[bf] = t.arrival_rate_rps()
    for bf, rate in rates.items():
        assert rate == pytest.approx(4.0, rel=0.35), (bf, rate)
    # and the realized rate is not monotonically inflated by burstiness
    assert max(rates.values()) < 2 * min(rates.values())


def test_diurnal_modulates_arrival_density():
    """With amplitude > 0 the peak half-cycle (sin > 0) must carry
    visibly more arrivals than the trough half-cycle at a fixed seed."""
    period = 50.0
    t = generate_trace(TraceSpec(
        n_requests=2000,
        arrivals=ArrivalSpec(kind="diurnal", rate_rps=4.0,
                             period_s=period, amplitude=0.8)), seed=23)
    phases = [(r.arrival_s % period) / period for r in t.requests]
    peak_half = sum(1 for p in phases if p < 0.5)
    trough_half = len(phases) - peak_half
    assert peak_half > 1.5 * trough_half, (peak_half, trough_half)


def test_diurnal_amplitude_zero_reduces_to_poisson():
    """amplitude=0 accepts every thinning candidate: arrivals are exactly
    homogeneous Poisson at rate_rps, with one extra rng.random() burned
    per arrival (the vestigial accept draw)."""
    import random
    rate, n, seed = 3.0, 120, 9
    t = generate_trace(TraceSpec(
        n_requests=n,
        arrivals=ArrivalSpec(kind="diurnal", rate_rps=rate,
                             amplitude=0.0)), seed=seed)
    rng = random.Random(seed)
    expect, clock = [], 0.0
    for _ in range(n):
        clock += rng.expovariate(rate)
        rng.random()                       # the always-true accept draw
        expect.append(clock)
    assert [r.arrival_s for r in t.requests] == expect


def test_spec_roundtrip():
    spec = TraceSpec(
        n_requests=10,
        arrivals=ArrivalSpec(kind="diurnal", rate_rps=2.0, amplitude=0.5),
        tenants=(TenantSpec(name="x", weight=2.0, priority=3,
                            lengths=LengthSpec(kind="uniform")),))
    assert TraceSpec.from_dict(spec.to_dict()) == spec
    # and the embedded meta makes the trace regenerable
    t = generate_trace(spec, seed=5)
    g = t.meta["generator"]
    assert generate_trace(TraceSpec.from_dict(g["spec"]), g["seed"]) == t


def test_length_distributions_respect_bounds():
    uni = generate_trace(TraceSpec(
        n_requests=60, tenants=(TenantSpec(lengths=LengthSpec(
            kind="uniform", isl_lo=100, isl_hi=200,
            osl_lo=10, osl_hi=20)),)), seed=0)
    assert all(100 <= r.isl <= 200 and 10 <= r.osl <= 20
               for r in uni.requests)
    fixed = generate_trace(TraceSpec(
        n_requests=5, tenants=(TenantSpec(lengths=LengthSpec(
            kind="fixed", isl=77, osl=11)),)), seed=0)
    assert all(r.isl == 77 and r.osl == 11 for r in fixed.requests)
    logn = generate_trace(TraceSpec(
        n_requests=200, tenants=(TenantSpec(lengths=LengthSpec(
            kind="lognormal", isl=500, osl=100, sigma=0.4)),)), seed=0)
    assert all(1 <= r.isl <= 2000 and 1 <= r.osl <= 400
               for r in logn.requests)
    share = generate_trace(TraceSpec(
        n_requests=300, tenants=(TenantSpec(lengths=LengthSpec(
            kind="sharegpt")),)), seed=0)
    assert len({r.isl for r in share.requests}) > 20   # a real mixture
    assert all(r.isl >= 1 and r.osl >= 1 for r in share.requests)


def test_tenant_mix_and_priorities():
    t = generate_trace(TraceSpec(
        n_requests=400,
        tenants=(TenantSpec(name="big", weight=0.9, priority=2),
                 TenantSpec(name="small", weight=0.1))), seed=9)
    counts = t.describe()["tenants"]
    assert counts["big"] > counts["small"]
    assert all(r.priority == 2 for r in t.requests if r.tenant == "big")


def test_bad_specs_rejected():
    with pytest.raises(ValueError, match="arrival kind"):
        ArrivalSpec(kind="lunar")
    with pytest.raises(ValueError, match="rate_rps"):
        ArrivalSpec(rate_rps=0)
    with pytest.raises(ValueError, match="amplitude"):
        ArrivalSpec(kind="diurnal", amplitude=1.5)
    with pytest.raises(ValueError, match="length kind"):
        LengthSpec(kind="zipf")
    with pytest.raises(ValueError, match="weight"):
        TenantSpec(weight=0)
    with pytest.raises(ValueError, match="duplicate"):
        TraceSpec(tenants=(TenantSpec(name="a"), TenantSpec(name="a")))
    with pytest.raises(ValueError, match="n_requests"):
        TraceSpec(n_requests=0)


# ---------------------------------------------------------------------------
# SLO / percentile helpers
# ---------------------------------------------------------------------------

def test_slo_spec():
    slo = SLOSpec(ttft_p99_ms=1000, tpot_p99_ms=50)
    assert SLOSpec.from_dict(slo.to_dict()) == slo
    assert slo.request_meets(0.5, 0.02)
    assert not slo.request_meets(1.5, 0.02)       # TTFT blown
    assert not slo.request_meets(0.5, 0.08)       # TPOT blown
    assert slo.request_meets(0.5, None)           # single-token output
    with pytest.raises(ValueError, match="positive"):
        SLOSpec(ttft_p99_ms=0)


def test_percentile_interpolation():
    vals = [1.0, 2.0, 3.0, 4.0]
    assert percentile(vals, 0.0) == 1.0
    assert percentile(vals, 1.0) == 4.0
    assert percentile(vals, 0.5) == pytest.approx(2.5)
    assert percentile([7.0], 0.99) == 7.0
    assert math.isnan(percentile([], 0.5))


# ---------------------------------------------------------------------------
# open-loop replay
# ---------------------------------------------------------------------------

def test_replay_counts_queueing_into_ttft():
    """All requests arriving at t=0 on a 1-slot engine: the Nth request's
    TTFT includes waiting for the previous N-1, so the p99 far exceeds
    the p50 even though every request is identical."""
    trace = constant_trace(isl=64, osl=16, n_requests=16, rate_rps=1e6)
    m = _sim(max_batch=1, max_num_tokens=256).replay(trace)
    assert m.completed == 16
    # TTFTs ramp linearly with queue position: the tail is ~2x the median
    assert m.ttft_ms["p99"] > 1.8 * m.ttft_ms["p50"]
    assert m.queue_depth_max > 0


def test_replay_idle_engine_jumps_to_next_arrival():
    trace = constant_trace(isl=32, osl=4, n_requests=5, rate_rps=0.5)
    m = _sim(max_batch=8, max_num_tokens=256).replay(trace)
    assert m.completed == 5
    # widely-spaced arrivals: no queueing, makespan spans the trace
    assert m.queue_depth_max == 0
    assert m.duration_s >= trace.duration_s
    assert m.ttft_ms["p99"] < 100.0


def test_replay_goodput_under_slo():
    trace = constant_trace(isl=64, osl=16, n_requests=12, rate_rps=1e6)
    strict = SLOSpec(ttft_p99_ms=1e-6, tpot_p99_ms=1e-6)
    loose = SLOSpec(ttft_p99_ms=1e9, tpot_p99_ms=1e9)
    sim = _sim(max_batch=2, max_num_tokens=256)
    m_strict = sim.replay(trace, slo=strict)
    m_loose = sim.replay(trace, slo=loose)
    assert m_strict.slo_attainment == 0.0 and m_strict.goodput_tok_s == 0.0
    assert m_loose.slo_attainment == 1.0
    assert m_loose.goodput_tok_s == pytest.approx(
        12 * 16 / m_loose.duration_s)
    assert m_loose.goodput_tok_s <= m_loose.throughput_tok_s + 1e-9


def test_replay_rejects_on_max_queue_and_counts_misses():
    trace = constant_trace(isl=32, osl=8, n_requests=20, rate_rps=1e6)
    m = _sim(max_batch=1, max_num_tokens=64, max_queue=4).replay(
        trace, slo=SLOSpec(ttft_p99_ms=1e9, tpot_p99_ms=1e9))
    assert m.rejected > 0
    assert m.completed + m.rejected + m.unfinished == 20
    # rejected requests count as SLO misses
    assert m.slo_attainment == pytest.approx(m.completed / 20)


def test_replay_accepts_plain_record_sequences():
    """Duck-typing: any records with arrival_s/isl/osl replay fine."""
    reqs = [TraceRequest(arrival_s=0.1 * i, isl=16, osl=4)
            for i in range(6)]
    m = _sim(max_batch=4, max_num_tokens=64).replay(reqs)
    assert m.completed == 6


def test_replay_truncated_flag_set_only_by_budget():
    trace = constant_trace(isl=32, osl=16, n_requests=30, rate_rps=100.0)
    full = _sim(max_batch=4, max_num_tokens=256).replay(trace)
    assert full.truncated is False
    cut = _sim(max_batch=4, max_num_tokens=256).replay(trace, max_steps=5)
    assert cut.truncated is True
    assert cut.unfinished > 0
    # a budget that exactly covers the work is not a truncation
    exact = _sim(max_batch=4, max_num_tokens=256).replay(
        trace, max_steps=full.steps)
    assert exact.completed == 30
    assert exact.truncated is False


def test_replay_metrics_to_dict_is_json_safe():
    trace = constant_trace(isl=16, osl=4, n_requests=4, rate_rps=10.0)
    m = _sim(max_batch=4, max_num_tokens=64).replay(
        trace, slo=SLOSpec(ttft_p99_ms=100, tpot_p99_ms=100))
    d = m.to_dict()
    assert "per_request" not in d
    json.dumps(d)
    assert d["slo"] == {"ttft_p99_ms": 100, "tpot_p99_ms": 100}


# ---------------------------------------------------------------------------
# priority admission (multi-tenant)
# ---------------------------------------------------------------------------

def test_priority_admission_orders_waiting_queue():
    sched = ContinuousBatchingScheduler(SchedulerConfig(
        max_batch=1, priority_admission=True))
    sched.add(Request(rid=0, isl=8, osl=2, priority=0))
    sched.add(Request(rid=1, isl=8, osl=2, priority=5))
    sched.add(Request(rid=2, isl=8, osl=2, priority=5))
    sched.add(Request(rid=3, isl=8, osl=2, priority=-1))
    assert [r.rid for r in sched.waiting] == [1, 2, 0, 3]


def test_priority_admission_off_is_fifo():
    sched = ContinuousBatchingScheduler(SchedulerConfig(max_batch=1))
    for rid, prio in ((0, 0), (1, 5), (2, -1)):
        sched.add(Request(rid=rid, isl=8, osl=2, priority=prio))
    assert [r.rid for r in sched.waiting] == [0, 1, 2]


def test_high_priority_tenant_gets_better_ttft():
    reqs = tuple(TraceRequest(arrival_s=0.0, isl=64, osl=8,
                              tenant="lo" if i % 2 else "hi",
                              priority=0 if i % 2 else 1)
                 for i in range(12))
    trace = WorkloadTrace(requests=reqs)
    sim = ServingSimulator(SchedulerConfig(max_batch=1, max_num_tokens=128,
                                           priority_admission=True), _lat)
    m = sim.replay(trace)
    hi = [ttft for ten, ttft, _ in m.per_request if ten == "hi"]
    lo = [ttft for ten, ttft, _ in m.per_request if ten == "lo"]
    assert max(hi) < min(lo)


# ---------------------------------------------------------------------------
# degenerate traces: explicit zeroed metrics, never NaN or masked division
# ---------------------------------------------------------------------------

def _assert_finite_replay(m):
    d = m.to_dict()
    json.dumps(d)
    for axis in ("ttft_ms", "tpot_ms"):
        for q, v in d[axis].items():
            assert math.isfinite(v), (axis, q, v)
    assert math.isfinite(d["throughput_tok_s"])
    assert math.isfinite(d["queue_depth_mean"])
    assert math.isfinite(d["slo_attainment"])
    assert math.isfinite(d["goodput_tok_s"])


def test_replay_empty_trace_returns_explicit_zeros():
    m = _sim(max_batch=2, max_num_tokens=64).replay(
        WorkloadTrace(requests=()), slo=SLOSpec())
    assert m.n_requests == 0 and m.completed == 0 and m.rejected == 0
    assert m.steps == 0 and m.duration_s == 0.0
    assert m.ttft_ms == {"p50": 0.0, "p95": 0.0, "p99": 0.0}
    assert m.tpot_ms == {"p50": 0.0, "p95": 0.0, "p99": 0.0}
    assert m.throughput_tok_s == 0.0
    assert m.queue_depth_mean == 0.0 and m.queue_depth_max == 0
    assert m.slo_attainment == 0.0 and m.goodput_tok_s == 0.0
    assert m.per_request == []
    _assert_finite_replay(m)


def test_replay_all_rejected_trace_returns_explicit_zeros():
    """max_queue=0 bounces every request: no steps ever execute, yet the
    metrics must stay finite and the rejections count as SLO misses."""
    trace = constant_trace(isl=32, osl=8, n_requests=10, rate_rps=1e6)
    m = _sim(max_batch=1, max_num_tokens=64, max_queue=0).replay(
        trace, slo=SLOSpec(ttft_p99_ms=1e9, tpot_p99_ms=1e9))
    assert m.rejected == 10 and m.completed == 0 and m.unfinished == 0
    assert m.steps == 0
    assert m.ttft_ms == {"p50": 0.0, "p95": 0.0, "p99": 0.0}
    assert m.slo_attainment == 0.0 and m.goodput_tok_s == 0.0
    _assert_finite_replay(m)


def test_replay_single_token_outputs_zero_tpot_percentiles():
    """osl==1 requests finish on prefill and carry no decode interval:
    the TPOT sample set is empty and must read as explicit zeros."""
    trace = constant_trace(isl=16, osl=1, n_requests=4, rate_rps=10.0)
    m = _sim(max_batch=4, max_num_tokens=64).replay(trace, slo=SLOSpec())
    assert m.completed == 4
    assert m.tpot_ms == {"p50": 0.0, "p95": 0.0, "p99": 0.0}
    assert m.slo_attainment == 1.0          # None tpot meets vacuously
    _assert_finite_replay(m)


# ---------------------------------------------------------------------------
# frontier replay: the skipped (disagg composite) path
# ---------------------------------------------------------------------------

def _aggregated_projection(describe="TP1 b4", tps=100.0):
    from repro.core.config import Projection
    return Projection(
        ttft_ms=50.0, tpot_ms=10.0, tokens_per_s_user=100.0,
        tokens_per_s_per_chip=tps, chips=1, batch_size=4,
        mode="aggregated",
        config={"describe": describe,
                "parallel": {"tp": 1, "pp": 1, "ep": 1, "dp": 1}})


def _disagg_projection(tps=500.0):
    from repro.core.config import Projection
    return Projection(
        ttft_ms=40.0, tpot_ms=8.0, tokens_per_s_user=125.0,
        tokens_per_s_per_chip=tps, chips=4, batch_size=16,
        mode="disaggregated",
        config={"describe": "1P(TP2 b2)1D(TP2 b16)",
                "prefill": {}, "decode": {}})


def test_candidate_from_projection_none_branches():
    from repro.core.config import Projection
    from repro.workloads import candidate_from_projection
    # disaggregated composites are not single-engine deployments
    assert candidate_from_projection(_disagg_projection()) is None
    # nor is a projection whose config never carried a parallelism block
    bare = Projection(ttft_ms=1.0, tpot_ms=1.0, tokens_per_s_user=1.0,
                      tokens_per_s_per_chip=1.0, chips=1, batch_size=1,
                      mode="aggregated", config={})
    assert candidate_from_projection(bare) is None
    # while a replayable aggregated projection rebuilds its candidate
    cand = candidate_from_projection(_aggregated_projection())
    assert cand is not None and cand.parallel.tp == 1


def test_replay_frontier_records_disagg_composite_as_skipped():
    """A disagg composite among the leaders must surface as a skipped
    entry — excluded from the goodput ranking, not silently dropped."""
    from repro.core.config import (ClusterSpec, SLA, WorkloadDescriptor)
    from repro.core.task_runner import TaskRunner
    from repro.workloads import replay_frontier
    w = WorkloadDescriptor(
        model="llama3.1-8b", isl=64, osl=16,
        sla=SLA(ttft_ms=1e6, min_tokens_per_s_user=None),
        cluster=ClusterSpec(n_chips=4), modes=("aggregated",), dtype="fp8")
    runner = TaskRunner(w)
    projections = [_disagg_projection(tps=500.0),
                   _aggregated_projection(tps=100.0)]
    trace = constant_trace(isl=64, osl=16, n_requests=6, rate_rps=10.0)
    section = replay_frontier(runner, projections, trace,
                              SLOSpec(ttft_p99_ms=1e9, tpot_p99_ms=1e9),
                              top_k=2)
    by_index = {c["index"]: c for c in section["candidates"]}
    skipped = by_index[0]
    assert skipped["mode"] == "disaggregated"
    assert skipped["replay"] is None
    assert "not replayable" in skipped["skipped"]
    replayed = by_index[1]
    assert replayed["skipped"] is None
    assert replayed["replay"]["completed"] == 6
    # rankings only cover replayable candidates
    assert section["ranking"] == [1]
    assert section["analytical_ranking"] == [1]
    assert section["best_index"] == 1
