"""Per-architecture smoke tests (deliverable f).

Every assigned architecture instantiates a REDUCED variant (<=4 layers,
d_model<=256, <=4 experts) and runs one forward + one train step on CPU,
asserting output shapes and the absence of NaNs.
"""
import jax
import jax.numpy as jnp
import pytest

from repro import models
from repro.configs import get_config, list_archs
from repro.training import optimizer as opt
from repro.training.train_step import make_train_step

ARCHS = list_archs()  # the 10 assigned archs (perf-model-only excluded)


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


def test_all_ten_assigned_archs_present():
    assert len(ARCHS) == 10
    families = {get_config(a).family for a in ARCHS}
    assert families == {"dense", "moe", "hybrid", "ssm", "audio", "vlm"}


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finiteness(arch, rng):
    cfg = get_config(arch).reduced()
    params = models.init_params(cfg, rng)
    B, S = 2, 16
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    extras = models.extra_train_inputs(cfg, B, S)
    hidden, aux = models.forward_train(params, cfg, tokens, **extras)
    assert hidden.shape == (B, S, cfg.d_model)
    assert jnp.isfinite(hidden).all()
    assert jnp.isfinite(aux)


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch, rng):
    cfg = get_config(arch).reduced()
    params = models.init_params(cfg, rng)
    state = opt.init(params)
    step = jax.jit(make_train_step(cfg))
    B, S = 2, 16
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    labels = jax.random.randint(jax.random.fold_in(rng, 1), (B, S), 0,
                                cfg.vocab_size)
    extras = models.extra_train_inputs(cfg, B, S)
    params2, state2, metrics = step(params, state, tokens, labels, **extras)
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["grad_norm"])
    assert int(state2.step) == 1
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved


@pytest.mark.parametrize("arch", ARCHS)
def test_exact_assigned_config(arch):
    """The full configs match the assignment table exactly."""
    cfg = get_config(arch)
    expected = {
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151_936, 128, 8),
        "h2o-danube-3-4b": (24, 3840, 32, 8, 10_240, 32_000, 0, 0),
        "qwen3-14b": (40, 5120, 40, 8, 17_408, 151_936, 0, 0),
        "whisper-small": (12, 768, 12, 12, 3072, 51_865, 0, 0),
        "qwen2-7b": (28, 3584, 28, 4, 18_944, 152_064, 0, 0),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256_000, 0, 0),
        "internlm2-1.8b": (24, 2048, 16, 8, 8192, 92_544, 0, 0),
        "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151_936, 0, 0),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50_304, 0, 0),
        "mixtral-8x22b": (56, 6144, 48, 8, 16_384, 32_768, 8, 2),
    }[arch]
    L, d, h, kv, ff, vocab, e, k = expected
    assert (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
            cfg.d_ff, cfg.vocab_size, cfg.num_experts, cfg.top_k) == \
        (L, d, h, kv, ff, vocab, e, k)
    assert cfg.source
