"""Speculative-decoding estimator (beyond-paper extension)."""
import pytest

from repro.core import ClusterSpec, PerfDatabase, SLA, WorkloadDescriptor
from repro.core.config import ParallelismConfig
from repro.core.speculative import SpeculativeEstimator, expected_accepted


def test_expected_accepted_limits():
    assert expected_accepted(4, 0.0) == pytest.approx(1.0)   # always 1 token
    assert expected_accepted(4, 1.0) == pytest.approx(5.0, rel=1e-2)
    lo, hi = expected_accepted(4, 0.3), expected_accepted(4, 0.9)
    assert 1.0 < lo < hi < 5.0


@pytest.fixture(scope="module")
def est():
    w = WorkloadDescriptor(
        model="qwen3-32b", isl=2048, osl=256,
        sla=SLA(ttft_ms=5000), cluster=ClusterSpec(n_chips=8),
        backend="repro-jax", dtype="fp8")
    return SpeculativeEstimator(w, draft_model="llama3.1-8b",
                                db=PerfDatabase("tpu_v5e", "repro-jax"))


def test_speedup_with_high_acceptance(est):
    par = ParallelismConfig(tp=8)
    p = est.evaluate(par, batch=4, gamma=4, acceptance=0.85)
    assert p.speedup_vs_autoregressive > 1.0
    assert p.accepted_per_round > 3.0
    assert p.draft_step_ms < p.verify_step_ms * 2


def test_low_acceptance_not_worth_it(est):
    par = ParallelismConfig(tp=8)
    p = est.evaluate(par, batch=4, gamma=6, acceptance=0.05)
    assert p.speedup_vs_autoregressive < 1.0


def test_best_gamma_monotone_in_acceptance(est):
    par = ParallelismConfig(tp=8)
    best_lo, _ = est.best_gamma(par, batch=4, acceptance=0.4)
    best_hi, _ = est.best_gamma(par, batch=4, acceptance=0.95)
    assert best_hi.gamma >= best_lo.gamma
    assert best_hi.tpot_ms <= best_lo.tpot_ms
