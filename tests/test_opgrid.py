"""Property tests (hypothesis) for the PerfDatabase interpolation grid."""
import math

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare environment: deterministic fallback shim
    from _hypothesis_compat import given, settings, st

from repro.core.perf_database import OpGrid


def _mono_grid():
    axes = [[1, 2, 4, 8, 16, 32], [128, 256, 512, 1024]]
    table = np.empty((6, 4))
    for i, m in enumerate(axes[0]):
        for j, n in enumerate(axes[1]):
            table[i, j] = 1e-6 * m * n + 5e-6
    return OpGrid(axes, table), axes


@given(st.floats(1, 32), st.floats(128, 1024))
@settings(max_examples=100, deadline=None)
def test_interpolation_within_bounds(m, n):
    grid, axes = _mono_grid()
    v = grid.query((m, n))
    lo = grid.table.min()
    hi = grid.table.max()
    assert lo * 0.999 <= v <= hi * 1.001


@given(st.floats(1, 32), st.floats(1, 32), st.floats(128, 1024))
@settings(max_examples=100, deadline=None)
def test_interpolation_monotone(m1, m2, n):
    """Monotone table -> monotone interpolation along each axis."""
    grid, _ = _mono_grid()
    a, b = sorted((m1, m2))
    assert grid.query((a, n)) <= grid.query((b, n)) * (1 + 1e-9)


def test_exact_on_grid_points():
    grid, axes = _mono_grid()
    for i, m in enumerate(axes[0]):
        for j, n in enumerate(axes[1]):
            assert grid.query((m, n)) == pytest.approx(grid.table[i, j],
                                                       rel=1e-9)


@given(st.floats(0.01, 100), st.floats(1, 10_000))
@settings(max_examples=60, deadline=None)
def test_clamps_outside_domain(m, n):
    grid, _ = _mono_grid()
    v = grid.query((m, n))
    assert math.isfinite(v) and v > 0


def test_json_roundtrip():
    grid, _ = _mono_grid()
    g2 = OpGrid.from_json(grid.to_json())
    assert g2.query((3.3, 300.0)) == pytest.approx(grid.query((3.3, 300.0)),
                                                   rel=1e-12)
