"""Property tests (hypothesis) for the PerfDatabase interpolation grid."""
import math

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare environment: deterministic fallback shim
    from _hypothesis_compat import given, settings, st

from repro.core.perf_database import OpGrid


def _mono_grid():
    axes = [[1, 2, 4, 8, 16, 32], [128, 256, 512, 1024]]
    table = np.empty((6, 4))
    for i, m in enumerate(axes[0]):
        for j, n in enumerate(axes[1]):
            table[i, j] = 1e-6 * m * n + 5e-6
    return OpGrid(axes, table), axes


@given(st.floats(1, 32), st.floats(128, 1024))
@settings(max_examples=100, deadline=None)
def test_interpolation_within_bounds(m, n):
    grid, axes = _mono_grid()
    v = grid.query((m, n))
    lo = grid.table.min()
    hi = grid.table.max()
    assert lo * 0.999 <= v <= hi * 1.001


@given(st.floats(1, 32), st.floats(1, 32), st.floats(128, 1024))
@settings(max_examples=100, deadline=None)
def test_interpolation_monotone(m1, m2, n):
    """Monotone table -> monotone interpolation along each axis."""
    grid, _ = _mono_grid()
    a, b = sorted((m1, m2))
    assert grid.query((a, n)) <= grid.query((b, n)) * (1 + 1e-9)


def test_exact_on_grid_points():
    grid, axes = _mono_grid()
    for i, m in enumerate(axes[0]):
        for j, n in enumerate(axes[1]):
            assert grid.query((m, n)) == pytest.approx(grid.table[i, j],
                                                       rel=1e-9)


@given(st.floats(0.01, 100), st.floats(1, 10_000))
@settings(max_examples=60, deadline=None)
def test_clamps_outside_domain(m, n):
    grid, _ = _mono_grid()
    v = grid.query((m, n))
    assert math.isfinite(v) and v > 0


def test_json_roundtrip():
    grid, _ = _mono_grid()
    g2 = OpGrid.from_json(grid.to_json())
    assert g2.query((3.3, 300.0)) == pytest.approx(grid.query((3.3, 300.0)),
                                                   rel=1e-12)


def test_json_roundtrip_equality():
    """Round-trip preserves axes and table EXACTLY, not just query-close:
    the calibration artifact's losslessness rides on this."""
    grid, _ = _mono_grid()
    blob = grid.to_json()
    g2 = OpGrid.from_json(blob)
    assert len(g2.axes) == len(grid.axes)
    for a, b in zip(g2.axes, grid.axes):
        assert np.array_equal(a, b)
    assert np.array_equal(g2.table, grid.table)
    assert g2.to_json() == blob                  # fixed point


def test_edge_clamping_at_axis_boundaries():
    """Queries beyond an axis clamp to the boundary cell exactly."""
    grid, axes = _mono_grid()
    lo_corner = grid.table[0, 0]
    hi_corner = grid.table[-1, -1]
    assert grid.query((0.01, 1.0)) == pytest.approx(lo_corner, rel=1e-9)
    assert grid.query((1e6, 1e9)) == pytest.approx(hi_corner, rel=1e-9)
    # clamping is per-axis: one coordinate out, the other interpolates
    mixed = grid.query((0.01, 300.0))
    assert mixed == pytest.approx(grid.query((axes[0][0], 300.0)), rel=1e-9)
    mixed = grid.query((3.0, 1e9))
    assert mixed == pytest.approx(grid.query((3.0, axes[1][-1])), rel=1e-9)


def test_exact_on_grid_hits_1d_and_3d():
    """Grid hits are exact for any dimensionality, not just the 2-D case."""
    ax1 = [1, 4, 16, 64]
    g1 = OpGrid.build((ax1,), lambda x: 3e-6 * x + 1e-6)
    for x in ax1:
        assert g1.query((x,)) == pytest.approx(3e-6 * x + 1e-6, rel=1e-9)
    ax3 = ([1, 8, 64], [128, 512], [256, 1024])
    g3 = OpGrid.build(ax3, lambda m, n, k: 1e-9 * m * n + 1e-8 * k)
    for m in ax3[0]:
        for n in ax3[1]:
            for k in ax3[2]:
                assert g3.query((m, n, k)) == pytest.approx(
                    1e-9 * m * n + 1e-8 * k, rel=1e-9)
