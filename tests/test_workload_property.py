"""Property tests for repro.workloads (hypothesis, with the deterministic
compat shim on bare environments): every seeded generator spec yields a
trace whose JSONL round-trip is exact and whose arrivals are sorted and
non-negative, and open-loop replay of a concurrency-equivalent constant
trace matches the closed-loop simulator's throughput within tolerance."""
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare environment: deterministic fallback shim
    from _hypothesis_compat import given, settings, st

from repro.serving.scheduler import SchedulerConfig
from repro.serving.sim import ServingSimulator, StepSpec
from repro.workloads import (ArrivalSpec, LengthSpec, TenantSpec, TraceSpec,
                             WorkloadTrace, constant_trace, generate_trace)


def _lat(spec: StepSpec) -> float:
    return 1e-3 + 1e-6 * sum(c for c, _ in spec.prefill) \
        + 1e-5 * len(spec.decode)


@given(st.sampled_from(["poisson", "bursty", "diurnal"]),
       st.sampled_from(["fixed", "uniform", "lognormal", "sharegpt"]),
       st.floats(0.2, 20.0),       # rate_rps
       st.integers(1, 60),         # n_requests
       st.integers(1, 3),          # n_tenants
       st.integers(0, 10_000))     # seed
@settings(max_examples=40, deadline=None)
def test_generated_trace_roundtrips_and_is_well_formed(
        arrival_kind, length_kind, rate, n, n_tenants, seed):
    spec = TraceSpec(
        n_requests=n,
        arrivals=ArrivalSpec(kind=arrival_kind, rate_rps=rate),
        tenants=tuple(
            TenantSpec(name=f"t{i}", weight=float(i + 1), priority=i,
                       lengths=LengthSpec(kind=length_kind))
            for i in range(n_tenants)))
    trace = generate_trace(spec, seed=seed)

    # exact JSONL round-trip (floats survive shortest-repr serialization)
    back = WorkloadTrace.from_jsonl(trace.to_jsonl())
    assert back == trace
    assert back.digest() == trace.digest()

    # arrivals sorted, non-negative; lengths positive; tenants known
    arrivals = [r.arrival_s for r in trace.requests]
    assert len(arrivals) == n
    assert arrivals == sorted(arrivals)
    assert all(a >= 0.0 for a in arrivals)
    names = {t.name for t in spec.tenants}
    for r in trace.requests:
        assert r.isl >= 1 and r.osl >= 1
        assert r.tenant in names

    # and (spec, seed) fully determines the trace
    assert generate_trace(spec, seed=seed) == trace


@given(st.integers(32, 256),       # isl
       st.integers(2, 24),         # osl
       st.sampled_from([2, 4, 8]))  # concurrency == max_batch
@settings(max_examples=15, deadline=None)
def test_replay_of_saturating_constant_trace_matches_closed_loop(
        isl, osl, concurrency):
    """A constant trace whose arrivals all but saturate the slot count is
    the open-loop twin of the closed-loop run: both keep `concurrency`
    requests in flight, so steady-state throughput must agree within
    tolerance (ramp-up/drain edges are the only difference)."""
    n = 8 * concurrency
    sim = ServingSimulator(
        SchedulerConfig(max_batch=concurrency, max_num_tokens=4096), _lat)
    closed = sim.run(isl=isl, osl=osl, concurrency=concurrency,
                     max_requests=n, warmup=0)
    # arrivals effectively instantaneous: the queue stays full like the
    # closed loop's injector
    trace = constant_trace(isl=isl, osl=osl, n_requests=n, rate_rps=1e9)
    replayed = sim.replay(trace)
    assert replayed.completed == closed.completed == n
    assert replayed.throughput_tok_s == pytest.approx(
        closed.throughput_tok_s, rel=0.15)
