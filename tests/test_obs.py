"""Tests for repro.obs — tracing spans, trace artifacts, metrics registry."""
import json
import math

import pytest

from repro.obs.metrics import (DEFAULT_BUCKETS, MetricsRegistry,
                               disable_metrics, enable_metrics, get_metrics)
from repro.obs.trace import (NULL_TRACER, TRACE_SCHEMA_VERSION, NullTracer,
                             SpanRecord, TraceArtifact, Tracer,
                             disable_tracing, enable_tracing, get_tracer,
                             set_tracer)


@pytest.fixture(autouse=True)
def _clean_globals():
    """Every test starts and ends with tracing/metrics disabled."""
    disable_tracing()
    disable_metrics()
    yield
    disable_tracing()
    disable_metrics()


# ---------------------------------------------------------------------------
# Tracer / Span
# ---------------------------------------------------------------------------

def _fake_clock():
    """Deterministic wallclock: each call advances 1 ms."""
    t = [0.0]

    def clock():
        t[0] += 1e-3
        return t[0]
    return clock


def test_span_nesting_parent_depth_and_virtual_time():
    tr = Tracer(clock=_fake_clock())
    with tr.span("outer", kind="a") as outer:
        tr.virtual_time = 2.0
        with tr.span("inner") as inner:
            tr.virtual_time = 5.0
        with tr.span("inner") as inner2:
            tr.virtual_time = 7.5
    assert outer.seq == 0 and outer.parent is None and outer.depth == 0
    assert inner.seq == 1 and inner.parent == 0 and inner.depth == 1
    assert inner2.seq == 2 and inner2.parent == 0 and inner2.depth == 1
    assert outer.v_start == 0.0 and outer.v_end == 7.5
    assert inner.v_start == 2.0 and inner.v_end == 5.0
    assert inner2.v_start == 5.0 and inner2.v_end == 7.5
    assert not tr._stack


def test_span_set_attaches_attrs_mid_span():
    tr = Tracer()
    with tr.span("s", a=1) as sp:
        sp.set(b=2)
    assert sp.attrs == {"a": 1, "b": 2}


def test_wall_by_name_aggregates_wall_seconds():
    tr = Tracer(clock=_fake_clock())
    with tr.span("x"):
        pass
    with tr.span("x"):
        pass
    with tr.span("y"):
        pass
    wall = tr.wall_by_name()
    assert set(wall) == {"x", "y"}
    assert wall["x"] == pytest.approx(2e-3)
    assert wall["y"] == pytest.approx(1e-3)


def test_artifact_refuses_open_spans():
    tr = Tracer()
    sp = tr.span("open-me")
    sp.__enter__()
    with pytest.raises(ValueError, match="1 span\\(s\\) open"):
        tr.artifact()
    sp.__exit__(None, None, None)
    assert tr.artifact().n_spans == 1


def test_misnested_exit_is_tolerated():
    tr = Tracer()
    a = tr.span("a").__enter__()
    b = tr.span("b").__enter__()
    a.__exit__(None, None, None)        # out of order
    b.__exit__(None, None, None)
    assert not tr._stack
    assert tr.artifact().n_spans == 2


def test_wall_ms_excluded_by_default_included_on_request():
    tr = Tracer(clock=_fake_clock())
    with tr.span("s"):
        pass
    bare = tr.artifact()
    assert bare.spans[0].wall_ms is None
    assert "wall_ms" not in bare.spans[0].to_dict()
    assert bare.wall_by_name() == {}
    walled = tr.artifact(include_wall=True)
    assert walled.spans[0].wall_ms == pytest.approx(1.0)
    assert walled.wall_by_name()["s"] == pytest.approx(1e-3)


def test_artifact_bytes_deterministic_without_wall():
    def build():
        tr = Tracer(clock=_fake_clock())
        with tr.span("a", n=3):
            tr.virtual_time += 1.25
            with tr.span("b"):
                tr.virtual_time += 0.5
        return tr.artifact(meta={"run": "x"})
    one, two = build(), build()
    assert one.to_jsonl() == two.to_jsonl()
    assert one.digest() == two.digest()


# ---------------------------------------------------------------------------
# TraceArtifact serialization
# ---------------------------------------------------------------------------

def _sample_artifact():
    tr = Tracer()
    with tr.span("root", model="m"):
        tr.virtual_time = 1.0
        with tr.span("child", n=2):
            tr.virtual_time = 3.0
    return tr.artifact(meta={"command": "test"})


def test_jsonl_round_trip_lossless():
    art = _sample_artifact()
    back = TraceArtifact.from_jsonl(art.to_jsonl())
    assert back == art
    assert back.digest() == art.digest()
    assert back.meta == {"command": "test"}


def test_jsonl_header_shape():
    art = _sample_artifact()
    lines = art.to_jsonl().splitlines()
    header = json.loads(lines[0])
    assert header == {"type": "header",
                      "schema_version": TRACE_SCHEMA_VERSION,
                      "n_spans": 2, "meta": {"command": "test"}}
    # span lines are sorted-key JSON
    for ln in lines[1:]:
        assert ln == json.dumps(json.loads(ln), sort_keys=True)


def test_save_load_round_trip(tmp_path):
    art = _sample_artifact()
    path = str(tmp_path / "trace.jsonl")
    art.save(path)
    assert TraceArtifact.load(path) == art


def test_from_jsonl_rejects_empty():
    with pytest.raises(ValueError, match="empty trace artifact"):
        TraceArtifact.from_jsonl("\n  \n")


def test_from_jsonl_rejects_missing_header():
    span = json.dumps({"seq": 0, "name": "x", "parent": None, "depth": 0,
                       "v_start": 0.0, "v_end": 0.0, "attrs": {}})
    with pytest.raises(ValueError, match="must start with a header"):
        TraceArtifact.from_jsonl(span + "\n")


def test_from_jsonl_rejects_unknown_version():
    bad = json.dumps({"type": "header", "schema_version": 99,
                      "n_spans": 0, "meta": {}})
    with pytest.raises(ValueError, match="unsupported trace schema version"):
        TraceArtifact.from_jsonl(bad + "\n")


def test_from_jsonl_rejects_malformed_span():
    header = json.dumps({"type": "header",
                         "schema_version": TRACE_SCHEMA_VERSION,
                         "n_spans": 1, "meta": {}})
    with pytest.raises(ValueError, match="malformed trace span record"):
        TraceArtifact.from_jsonl(header + "\n" + json.dumps({"seq": 0}) + "\n")


def test_from_jsonl_rejects_span_count_mismatch():
    art = _sample_artifact()
    lines = art.to_jsonl().splitlines()
    with pytest.raises(ValueError, match="declares 2 spans, found 1"):
        TraceArtifact.from_jsonl("\n".join(lines[:2]) + "\n")


def test_artifact_validates_seq_order_and_parent():
    rec = SpanRecord(seq=1, name="x", parent=None, depth=0,
                     v_start=0.0, v_end=0.0, attrs={})
    with pytest.raises(ValueError, match="out of order"):
        TraceArtifact(spans=(rec,))
    root = SpanRecord(seq=0, name="r", parent=None, depth=0,
                      v_start=0.0, v_end=0.0, attrs={})
    fwd = SpanRecord(seq=1, name="c", parent=1, depth=1,
                     v_start=0.0, v_end=0.0, attrs={})
    with pytest.raises(ValueError, match="parent 1 not yet open"):
        TraceArtifact(spans=(root, fwd))


# ---------------------------------------------------------------------------
# null tracer + global install
# ---------------------------------------------------------------------------

def test_null_tracer_is_default_and_shares_one_span():
    assert get_tracer() is NULL_TRACER
    sp1 = NULL_TRACER.span("anything", n=1)
    sp2 = NULL_TRACER.span("other")
    assert sp1 is sp2                       # no allocation per call
    with sp1 as s:
        assert s.set(x=1) is s
    # instrumented code reads v_start off the null span without branching
    assert sp1.v_start == 0.0 and sp1.v_end == 0.0
    NULL_TRACER.virtual_time = 4.0          # writable, ignored
    assert NULL_TRACER.wall_by_name() == {}
    NULL_TRACER.virtual_time = 0.0


def test_enable_disable_tracing_round_trip():
    t = enable_tracing()
    assert isinstance(t, Tracer) and get_tracer() is t
    disable_tracing()
    assert get_tracer() is NULL_TRACER
    mine = Tracer()
    assert enable_tracing(mine) is mine and get_tracer() is mine
    set_tracer(None)
    assert get_tracer() is NULL_TRACER
    assert isinstance(NULL_TRACER, NullTracer)


# ---------------------------------------------------------------------------
# MetricsRegistry
# ---------------------------------------------------------------------------

def test_counter_inc_and_lookup():
    reg = MetricsRegistry()
    reg.inc("ops_total")
    reg.inc("ops_total", 2.5)
    assert reg.counter_value("ops_total") == pytest.approx(3.5)
    assert reg.counter_value("missing") == 0.0


def test_counter_labels_and_totals():
    reg = MetricsRegistry()
    reg.inc("ops_total", 2, family="gemm", path="grid")
    reg.inc("ops_total", 3, family="attn_decode", path="grid")
    reg.inc("ops_total", 5, path="grid", family="gemm")   # order-insensitive
    assert reg.counter_value("ops_total", family="gemm", path="grid") == 7
    assert reg.counter_total("ops_total") == 10
    flat = reg.to_dict()["counters"]
    assert flat["ops_total{family=gemm,path=grid}"] == 7
    assert flat["ops_total{family=attn_decode,path=grid}"] == 3


def test_counter_rejects_negative():
    reg = MetricsRegistry()
    with pytest.raises(ValueError, match="cannot decrease"):
        reg.inc("ops_total", -1)


def test_gauges_overwrite():
    reg = MetricsRegistry()
    reg.set_gauge("replicas", 2)
    reg.set_gauge("replicas", 4)
    assert reg.to_dict()["gauges"]["replicas"] == 4


def test_histogram_buckets_sum_count_and_overflow():
    reg = MetricsRegistry(buckets=(0.1, 1.0, 10.0))
    for v in (0.05, 0.5, 5.0, 50.0):
        reg.observe("latency_s", v)
    h = reg.to_dict()["histograms"]["latency_s"]
    assert h["buckets"] == [0.1, 1.0, 10.0]
    assert h["counts"] == [1, 1, 1, 1]      # last slot is +Inf overflow
    assert h["count"] == 4
    assert h["sum"] == pytest.approx(55.55)


def test_default_buckets_strictly_increasing():
    assert list(DEFAULT_BUCKETS) == sorted(DEFAULT_BUCKETS)
    assert len(set(DEFAULT_BUCKETS)) == len(DEFAULT_BUCKETS)
    with pytest.raises(ValueError):
        MetricsRegistry(buckets=(1.0, 1.0))


def test_to_dict_deterministic_and_sorted():
    def build():
        reg = MetricsRegistry()
        reg.inc("b_total", 1, z=1, a=2)
        reg.inc("a_total", 2)
        reg.set_gauge("g", 3.0)
        reg.observe("h", 0.2)
        return reg
    one, two = build().to_dict(), build().to_dict()
    assert json.dumps(one, sort_keys=True) == json.dumps(two, sort_keys=True)
    assert list(one["counters"]) == sorted(one["counters"])


def test_to_prometheus_format():
    reg = MetricsRegistry(buckets=(1.0, 2.0))
    reg.inc("ops_total", 3, path="grid")
    reg.set_gauge("replicas", 2)
    reg.observe("lat_s", 0.5)
    reg.observe("lat_s", 1.5)
    text = reg.to_prometheus()
    assert "# TYPE ops_total counter" in text
    assert 'ops_total{path="grid"} 3' in text
    assert "# TYPE replicas gauge" in text
    assert "replicas 2" in text
    assert "# TYPE lat_s histogram" in text
    # cumulative buckets
    assert 'lat_s_bucket{le="1"} 1' in text
    assert 'lat_s_bucket{le="2"} 2' in text
    assert 'lat_s_bucket{le="+Inf"} 2' in text
    assert "lat_s_sum 2" in text
    assert "lat_s_count 2" in text
    assert text.endswith("\n")


def test_finite_and_reset():
    reg = MetricsRegistry()
    reg.inc("ops_total", 1)
    reg.set_gauge("g", 2.0)
    reg.observe("h", 0.1)
    assert reg.finite()
    reg.set_gauge("bad", math.inf)
    assert not reg.finite()
    reg.reset()
    d = reg.to_dict()
    assert d == {"counters": {}, "gauges": {}, "histograms": {}}


def test_enable_disable_metrics_round_trip():
    assert get_metrics() is None
    reg = enable_metrics()
    assert isinstance(reg, MetricsRegistry) and get_metrics() is reg
    disable_metrics()
    assert get_metrics() is None
    mine = MetricsRegistry()
    assert enable_metrics(mine) is mine and get_metrics() is mine
