"""BenchArtifact schema v1: lossless round-trip, golden fixture, and
the wallclock-free canonical digest."""
import dataclasses
import json
import os

import pytest

from repro.obs.bench import (BENCH_KIND, BENCH_SCHEMA_VERSION, BenchArtifact,
                             BenchRecord, BenchTiming)

FIXTURE = os.path.join(os.path.dirname(__file__), "fixtures",
                       "bench_quick_v1.json")
GOLDEN_DIGEST = "e5add6d213f71f45"


def _record(name="bench_a", status="ok", counters=None, samples=(1000.0,),
            phases=None, derived="x=1", error=""):
    return BenchRecord(name=name, status=status,
                       timing=BenchTiming.from_samples(samples),
                       counters={"work_total": 7.0} if counters is None
                       else counters,
                       phases={"phase.a": 0.5} if phases is None else phases,
                       derived=derived, error=error)


def _artifact(records=None, env=None, created_at="2026-01-01T00:00:00Z"):
    return BenchArtifact(
        suite="quick", created_at=created_at,
        environment={"platform": "test", "repro": {"CHUNK": 64}}
        if env is None else env,
        records=[_record()] if records is None else records)


# ---------------------------------------------------------------------------
# round trip
# ---------------------------------------------------------------------------

def test_round_trip_equality():
    art = _artifact(records=[_record("a"), _record("b", counters={"k": 1.0})])
    again = BenchArtifact.from_json(art.to_json())
    assert again == art
    assert again.digest() == art.digest()


def test_save_load(tmp_path):
    art = _artifact()
    path = str(tmp_path / "bench.json")
    art.save(path)
    assert BenchArtifact.load(path) == art


def test_timing_round_trip_preserves_samples():
    t = BenchTiming.from_samples([3.0, 1.0, 2.0, 10.0])
    again = BenchTiming.from_dict(t.to_dict())
    assert again == t
    assert again.samples_us == (3.0, 1.0, 2.0, 10.0)


# ---------------------------------------------------------------------------
# golden fixture
# ---------------------------------------------------------------------------

def test_golden_fixture_loads():
    art = BenchArtifact.load(FIXTURE)
    assert art.schema_version == BENCH_SCHEMA_VERSION
    assert art.suite == "quick"
    assert art.names == ["table1_search_efficiency",
                         "workload_goodput_rerank", "roofline_from_dryrun"]
    err = art.record("roofline_from_dryrun")
    assert err.status == "error" and "FileNotFoundError" in err.error
    ok = art.record("table1_search_efficiency")
    assert ok.timing.n == 3
    assert ok.counters["repro_search_chunks_total"] == 2.0
    assert ok.phases["search.chunk"] == pytest.approx(0.084)


def test_golden_fixture_byte_stable():
    """from_json(text).to_json() reproduces the file byte for byte —
    the lossless-round-trip acceptance criterion."""
    with open(FIXTURE) as f:
        text = f.read()
    assert BenchArtifact.from_json(text).to_json() + "\n" == text


def test_golden_fixture_digest_pinned():
    """The canonical digest is part of the v1 contract: it may only
    change with a schema bump."""
    assert BenchArtifact.load(FIXTURE).digest() == GOLDEN_DIGEST


# ---------------------------------------------------------------------------
# canonical digest excludes wallclock
# ---------------------------------------------------------------------------

def test_digest_ignores_wallclock_fields():
    art = _artifact()
    noisy = BenchArtifact(
        suite=art.suite, created_at="2031-12-31T23:59:59Z",
        environment=art.environment, notes="a different note",
        records=[dataclasses.replace(
            art.records[0],
            timing=BenchTiming.from_samples([99999.0, 1.0]),
            phases={"phase.a": 123.0, "phase.b": 4.0},
            derived="totally different")])
    assert noisy.digest() == art.digest()
    assert noisy.to_dict() != art.to_dict()


def test_digest_sees_counters_and_status():
    art = _artifact()
    bumped = BenchArtifact(
        suite=art.suite, created_at=art.created_at,
        environment=art.environment,
        records=[dataclasses.replace(art.records[0],
                                     counters={"work_total": 8.0})])
    assert bumped.digest() != art.digest()
    errored = BenchArtifact(
        suite=art.suite, created_at=art.created_at,
        environment=art.environment,
        records=[dataclasses.replace(art.records[0], status="error",
                                     error="boom")])
    assert errored.digest() != art.digest()


def test_digest_sees_environment():
    art = _artifact()
    other = _artifact(env={"platform": "test", "repro": {"CHUNK": 1}})
    assert other.digest() != art.digest()


def test_counters_digest_tracks_only_counters():
    a = _record(counters={"k": 1.0})
    b = dataclasses.replace(a, timing=BenchTiming.from_samples([5.0]),
                            phases={}, derived="other")
    assert a.counters_digest() == b.counters_digest()
    c = dataclasses.replace(a, counters={"k": 2.0})
    assert c.counters_digest() != a.counters_digest()


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------

def test_rejects_wrong_kind():
    d = _artifact().to_dict()
    d["kind"] = "repro-calibration"
    with pytest.raises(ValueError, match="not a bench artifact"):
        BenchArtifact.from_dict(d)


def test_rejects_unknown_schema_version():
    d = _artifact().to_dict()
    d["schema_version"] = 99
    with pytest.raises(ValueError, match="unsupported bench schema_version"):
        BenchArtifact.from_dict(d)


def test_rejects_duplicate_records():
    with pytest.raises(ValueError, match="duplicate"):
        _artifact(records=[_record("a"), _record("a")])


def test_rejects_bad_status():
    with pytest.raises(ValueError, match="status"):
        _record(status="flaky")


def test_timing_requires_samples():
    with pytest.raises(ValueError):
        BenchTiming.from_samples([])


# ---------------------------------------------------------------------------
# timing stats
# ---------------------------------------------------------------------------

def test_timing_stats():
    t = BenchTiming.from_samples([40.0, 10.0, 30.0, 20.0])
    assert t.n == 4
    assert t.min_us == 10.0
    assert t.median_us == 25.0
    # statistics.quantiles exclusive method: q1=12.5, q3=37.5
    assert t.iqr_us == pytest.approx(25.0)
    single = BenchTiming.from_samples([42.0])
    assert single.median_us == single.min_us == 42.0
    assert single.iqr_us == 0.0


def test_artifact_json_is_sorted_and_plain():
    blob = json.loads(_artifact().to_json())
    assert blob["kind"] == BENCH_KIND
    rec = blob["records"][0]
    assert list(rec["counters"]) == sorted(rec["counters"])
    assert list(rec["phases"]) == sorted(rec["phases"])
