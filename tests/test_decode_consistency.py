"""Prefill + decode must reproduce the full forward pass exactly (the
serving path is algebraically the training path) for every family,
including SWA ring buffers and recurrent state threading."""
import jax
import jax.numpy as jnp
import pytest

from repro import models
from repro.configs import get_config, list_archs
from repro.models import common as cm


def _last_logits(cfg, params, hidden):
    if cfg.family == "audio":
        from repro.models import encdec
        return encdec._final_logits(params, cfg, hidden[:, -1:])
    return cm.lm_logits(params["embed"], hidden[:, -1:], cfg)


@pytest.mark.parametrize("arch", list_archs())
def test_decode_matches_forward(arch):
    rng = jax.random.PRNGKey(0)
    cfg = get_config(arch).reduced()
    params = models.init_params(cfg, rng)
    B, S = 2, 12
    toks = jax.random.randint(rng, (B, S + 1), 0, cfg.vocab_size)
    extras = models.extra_train_inputs(cfg, B, S + 1)
    hidden, _ = models.forward_train(params, cfg, toks, **extras)
    ref = _last_logits(cfg, params, hidden)

    pex = models.extra_train_inputs(cfg, B, S)
    if cfg.family == "vlm":
        pex["mrope_positions"] = extras["mrope_positions"][:, :, :S]
    logits_p, cache = models.prefill(params, cfg, toks[:, :S],
                                     max_len=S + 8, **pex)
    dex = {}
    if cfg.family == "vlm":
        dex["mrope_positions"] = extras["mrope_positions"][:, :, S:S + 1]
    logits_d, cache2 = models.decode_step(params, cfg, toks[:, S:S + 1],
                                          cache, **dex)
    err = float(jnp.max(jnp.abs(logits_d.astype(jnp.float32)
                                - ref.astype(jnp.float32))))
    assert err < 1e-3, f"{arch}: decode diverges from forward by {err}"


@pytest.mark.parametrize("arch", ["h2o-danube-3-4b", "recurrentgemma-2b"])
def test_sliding_window_ring_buffer(arch):
    """Decode far past the window: ring buffer must keep matching a fresh
    prefill over the visible window."""
    rng = jax.random.PRNGKey(1)
    cfg = get_config(arch).reduced()   # window 16
    params = models.init_params(cfg, rng)
    B = 1
    total = 40
    toks = jax.random.randint(rng, (B, total), 0, cfg.vocab_size)

    # path A: prefill 8, decode the rest step by step
    lg, cache = models.prefill(params, cfg, toks[:, :8], max_len=64)
    for t in range(8, total):
        lg, cache = models.decode_step(params, cfg, toks[:, t:t + 1], cache)

    # path B: single prefill over everything
    lg_ref, _ = models.prefill(params, cfg, toks, max_len=64)
    # both are logits after the final token
    err = float(jnp.max(jnp.abs(lg - lg_ref)))
    assert err < 2e-3, f"{arch} ring buffer drift: {err}"


def test_multi_token_greedy_decode_deterministic():
    cfg = get_config("internlm2-1.8b").reduced()
    params = models.init_params(cfg, jax.random.PRNGKey(2))
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, 10), 0,
                              cfg.vocab_size)
    seqs = []
    for _ in range(2):
        lg, cache = models.prefill(params, cfg, toks, max_len=32)
        out = [int(jnp.argmax(lg[0, -1]))]
        for _ in range(6):
            lg, cache = models.decode_step(
                params, cfg, jnp.asarray([[out[-1]]]), cache)
            out.append(int(jnp.argmax(lg[0, -1])))
        seqs.append(out)
    assert seqs[0] == seqs[1]
