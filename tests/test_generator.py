"""Generator (§4.1): launch artifacts for every backend with resolved flags."""
import json

import pytest

from repro.core import (ClusterSpec, PerfDatabase, SLA, TaskRunner,
                        WorkloadDescriptor, generate)
from repro.core.backends.base import all_backends, get_backend
from repro.core.generator import resolve_kv_fraction
from repro.core.config import ParallelismConfig


def _workload(backend):
    return WorkloadDescriptor(
        model="llama3.1-8b", isl=1024, osl=256,
        sla=SLA(ttft_ms=2000, min_tokens_per_s_user=10),
        cluster=ClusterSpec(n_chips=16), backend=backend, dtype="fp8")


@pytest.fixture(scope="module")
def results():
    out = {}
    for be in all_backends():
        w = _workload(be)
        r = TaskRunner(w, PerfDatabase("tpu_v5e", be)).run()
        assert r.best is not None
        out[be] = (w, r)
    return out


@pytest.mark.parametrize("backend", ["repro-jax", "trtllm", "vllm", "sglang"])
def test_launch_artifact(results, backend):
    w, r = results[backend]
    lc = generate(w, r.best)
    assert lc.backend == backend
    assert w.model in lc.command
    be = get_backend(backend)
    assert lc.command.startswith(be.launcher)
    raw = json.loads(lc.to_json())
    assert raw["mode"] in ("static", "aggregated", "disaggregated")
    if raw["mode"] != "disaggregated":
        kv = raw["runtime_flags"]["kv_cache_mem_fraction"]
        assert 0.0 < kv <= 0.95
        assert be.flags["kv_cache_mem_fraction"] in lc.command


def test_backend_flag_vocabulary_differs(results):
    cmds = {be: generate(w, r.best).command for be, (w, r) in results.items()}
    # trtllm-style flag appears only in its own command
    assert "--kv_cache_free_gpu_mem_fraction" not in cmds["vllm"]
    assert any("kv_cache_free_gpu_mem_fraction" in cmds["trtllm"]
               or "--prefill" in cmds["trtllm"]
               for _ in [0])


def test_kv_fraction_monotone_in_batch():
    w = _workload("repro-jax")
    par = ParallelismConfig(tp=8)
    f_small = resolve_kv_fraction(w, par, 2)
    f_big = resolve_kv_fraction(w, par, 64)
    assert f_small <= f_big <= 0.95


def test_disagg_artifact():
    w = _workload("repro-jax")
    r = TaskRunner(w, PerfDatabase("tpu_v5e", "repro-jax")).run()
    dis = [p for p in r.projections if p.mode == "disaggregated"]
    if not dis:
        pytest.skip("no disagg candidate fit this workload")
    lc = generate(w, dis[0])
    assert "--disaggregated" in lc.command
    assert lc.raw["prefill_workers"]["count"] >= 1
    assert lc.raw["decode_workers"]["count"] >= 1
