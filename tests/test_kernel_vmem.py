"""Static VMEM-budget checks: every Pallas kernel's default BlockSpec
working set must fit TPU v5e VMEM (~16 MiB usable) with headroom for
double buffering — the structural reasoning the §Perf Pallas hints call
for (no wall-clock trace available off-TPU)."""
import pytest

VMEM_BUDGET = 16 * 2 ** 20
DOUBLE_BUFFER = 2           # pallas pipelines in/out blocks


def test_flash_attention_vmem():
    from repro.kernels.flash_attention import DEFAULT_BQ, DEFAULT_BK
    D = 128
    working = (
        DEFAULT_BQ * D * 2            # q block bf16
        + 2 * DEFAULT_BK * D * 2      # k, v blocks
        + DEFAULT_BQ * D * 4          # acc scratch f32
        + 2 * DEFAULT_BQ * 4          # m, l
        + DEFAULT_BQ * DEFAULT_BK * 4  # logits transient
    ) * DOUBLE_BUFFER
    assert working < VMEM_BUDGET, working
    # and MXU alignment
    assert DEFAULT_BQ % 8 == 0 and DEFAULT_BK % 128 == 0


def test_decode_attention_vmem():
    from repro.kernels.decode_attention import DEFAULT_BK
    G, D = 16, 128
    working = (
        G * D * 2 + 2 * DEFAULT_BK * D * 2
        + G * D * 4 + 2 * G * 4 + G * DEFAULT_BK * 4
    ) * DOUBLE_BUFFER
    assert working < VMEM_BUDGET, working


def test_rglru_scan_vmem():
    from repro.kernels.rglru_scan import DEFAULT_BS, DEFAULT_BW
    working = (3 * DEFAULT_BS * DEFAULT_BW * 4 + DEFAULT_BW * 4) \
        * DOUBLE_BUFFER
    assert working < VMEM_BUDGET
    assert DEFAULT_BW % 128 == 0


def test_moe_gemm_vmem():
    from repro.kernels.moe_gemm import DEFAULT_BC, DEFAULT_BD, DEFAULT_BF
    working = (DEFAULT_BC * DEFAULT_BD * 2 + DEFAULT_BD * DEFAULT_BF * 2
               + DEFAULT_BC * DEFAULT_BF * 4) * DOUBLE_BUFFER
    assert working < VMEM_BUDGET
    assert DEFAULT_BC % 8 == 0 and DEFAULT_BF % 128 == 0 \
        and DEFAULT_BD % 128 == 0
