"""The unified `repro.api` facade: fluent Configurator, schema-versioned
SearchReport round-trip, backend registry, and CLI equivalence."""
import dataclasses
import json
import re
import time

import pytest

from repro.api import Comparison, Configurator, SCHEMA_VERSION, SearchReport
from repro.core import (ClusterSpec, PerfDatabase, SLA, TaskRunner,
                        WorkloadDescriptor, cli)
from repro.core.backends.base import (BackendProfile, all_backends,
                                      get_backend, register_backend,
                                      unregister_backend)


def _small_configurator(**kw):
    return (Configurator.for_model(kw.get("model", "llama3.1-8b"))
            .traffic(isl=kw.get("isl", 256), osl=kw.get("osl", 64))
            .sla(ttft_ms=2000, min_tokens_per_s_user=10)
            .cluster(chips=kw.get("chips", 8))
            .backend("repro-jax").dtype("fp8")
            .modes(*kw.get("modes", ("aggregated",))))


@pytest.fixture(scope="module")
def report():
    return _small_configurator().search()


# ---------------------------------------------------------------------------
# SearchReport round-trip
# ---------------------------------------------------------------------------

def test_report_json_roundtrip(report):
    blob = report.to_json()
    assert json.loads(blob)["schema_version"] == SCHEMA_VERSION
    back = SearchReport.from_json(blob)
    assert back == report
    # second hop is stable too
    assert SearchReport.from_json(back.to_json()) == report


def test_report_roundtrip_with_disagg_and_launch():
    rep = _small_configurator(isl=128, osl=32, chips=4,
                              modes=("aggregated", "disaggregated")).search()
    assert rep.launch is not None
    back = SearchReport.from_json(rep.to_json())
    assert back == rep
    assert back.launch.command == rep.launch.command
    if rep.disagg is not None:
        assert back.disagg["describe"] == rep.disagg["describe"]


def test_report_rejects_unknown_schema_version(report):
    d = report.to_dict()
    d["schema_version"] = SCHEMA_VERSION + 1
    with pytest.raises(ValueError, match="schema_version"):
        SearchReport.from_dict(d)


def test_report_rejects_truncated_payload():
    with pytest.raises(ValueError, match="malformed"):
        SearchReport.from_dict({"schema_version": SCHEMA_VERSION})


def test_report_views(report):
    assert report.best is report.projections[report.best_index]
    assert all(f in report.projections for f in report.frontier)
    assert report.top_k(3)
    assert "candidates" in report.summary()


# ---------------------------------------------------------------------------
# Configurator: eager validation
# ---------------------------------------------------------------------------

def test_unknown_model_lists_choices():
    with pytest.raises(ValueError) as e:
        Configurator.for_model("gpt-99")
    assert "qwen3-32b" in str(e.value)


def test_unknown_backend_lists_choices():
    with pytest.raises(ValueError) as e:
        Configurator.for_model("llama3.1-8b").backend("tensorflow-serving")
    assert "repro-jax" in str(e.value)


def test_unknown_platform_lists_choices():
    with pytest.raises(ValueError) as e:
        Configurator.for_model("llama3.1-8b").cluster(8, platform="tpu_v9")
    assert "tpu_v5e" in str(e.value)


def test_invalid_traffic_and_modes():
    c = Configurator.for_model("llama3.1-8b")
    with pytest.raises(ValueError):
        c.traffic(isl=0, osl=64)
    with pytest.raises(ValueError, match="mode"):
        c.modes("quantum")
    with pytest.raises(ValueError, match="traffic"):
        c.search()   # traffic never set


def test_compare_without_traffic_is_clean_error():
    c = Configurator.for_model("llama3.1-8b")
    with pytest.raises(ValueError, match="isl and osl"):
        c.compare([{"isl": 128}])    # osl never set anywhere


def test_unknown_draft_model():
    c = _small_configurator()
    with pytest.raises(ValueError, match="draft model"):
        c.speculative("not-a-model")


# ---------------------------------------------------------------------------
# Memoized search: second run on the same instance is faster
# ---------------------------------------------------------------------------

def test_second_search_is_faster_and_hits_seq_memo():
    c = _small_configurator()
    t0 = time.perf_counter()
    r1 = c.search()
    t_cold = time.perf_counter() - t0
    db = c.database()
    hits_before = db.stats.seq_hits
    t0 = time.perf_counter()
    r2 = c.search()
    t_warm = time.perf_counter() - t0
    assert db.stats.seq_hits > hits_before   # op-sequence memo answered
    # cold includes grid collection + uncached pricing, so the margin is
    # large; best-of-two warm runs keeps scheduler noise from flaking it
    t0 = time.perf_counter()
    c.search()
    t_warm = min(t_warm, time.perf_counter() - t0)
    assert t_warm < t_cold                   # measurably faster than cold
    # same results both times (modulo timing metadata)
    assert r1.projections == r2.projections
    assert r1.best_index == r2.best_index


def test_sequence_memo_tolerates_unhashable_ops():
    @dataclasses.dataclass(eq=True)          # eq without frozen -> unhashable
    class WeirdOp:
        flops_val: float = 1e9

        def flops(self):
            return self.flops_val

        def bytes(self):
            return 1e6

    db = PerfDatabase("tpu_v5e", "repro-jax", use_grid=False)
    assert db.sequence_latency([WeirdOp(), (WeirdOp(), 2)]) > 0


# ---------------------------------------------------------------------------
# Facade == legacy TaskRunner path
# ---------------------------------------------------------------------------

def test_facade_matches_taskrunner():
    w = WorkloadDescriptor(
        model="llama3.1-8b", isl=256, osl=64,
        sla=SLA(ttft_ms=2000, min_tokens_per_s_user=10),
        cluster=ClusterSpec(n_chips=8), backend="repro-jax", dtype="fp8",
        modes=("aggregated",))
    legacy = TaskRunner(w, PerfDatabase("tpu_v5e", "repro-jax")).run()
    rep = _small_configurator().search()
    assert rep.workload == w
    assert [dataclasses.asdict(p) for p in rep.projections] \
        == [dataclasses.asdict(p) for p in legacy.projections]
    assert dataclasses.asdict(rep.best) == dataclasses.asdict(legacy.best)
    assert rep.n_candidates == legacy.n_candidates


# ---------------------------------------------------------------------------
# Backend registry
# ---------------------------------------------------------------------------

def _dummy_profile(name="test-dummy"):
    return BackendProfile(
        name=name, step_overhead=1e-6, chunk_overhead=1e-6,
        runtime_mem_overhead=0.01, default_max_num_tokens=8192,
        graph_capture_saving=0.5)


def test_registry_rejects_duplicates():
    register_backend("test-dummy", capabilities=("aggregated",))(
        _dummy_profile)
    try:
        with pytest.raises(ValueError, match="already registered"):
            register_backend("test-dummy")(_dummy_profile)
        # resolved lazily, capabilities attached from the registration
        prof = get_backend("test-dummy")
        assert prof.capabilities == frozenset({"aggregated"})
        assert "test-dummy" in all_backends()
        # the facade accepts the plugin without core edits...
        c = Configurator.for_model("llama3.1-8b").backend("test-dummy")
        # ...and enforces its declared capabilities
        with pytest.raises(ValueError, match="capabilit"):
            c.traffic(isl=64, osl=16).modes("disaggregated").workload()
    finally:
        unregister_backend("test-dummy")
    assert "test-dummy" not in all_backends()


def test_legacy_register_preserves_declared_capabilities():
    from repro.core.backends.base import register
    register_backend("test-dummy3", capabilities=("aggregated",))(
        lambda: _dummy_profile("test-dummy3"))
    try:
        # calibration-style re-registration without explicit capabilities
        register(dataclasses.replace(get_backend("test-dummy3"),
                                     step_overhead=9e-6))
        assert get_backend("test-dummy3").capabilities \
            == frozenset({"aggregated"})
    finally:
        unregister_backend("test-dummy3")


def test_registry_rejects_unknown_capability():
    with pytest.raises(ValueError, match="capabilities"):
        register_backend("test-dummy2", capabilities=("teleportation",))


def test_builtin_backends_registered_lazily():
    assert set(all_backends()) >= {"repro-jax", "trtllm", "vllm", "sglang"}
    with pytest.raises(KeyError, match="unknown backend"):
        get_backend("definitely-not-registered")


# ---------------------------------------------------------------------------
# CLI: legacy flags == search subcommand; --json is valid JSON
# ---------------------------------------------------------------------------

_CLI_ARGS = ["--model", "llama3.1-8b", "--isl", "256", "--osl", "64",
             "--ttft", "2000", "--min-speed", "10", "--chips", "8",
             "--dtype", "fp8", "--modes", "aggregated"]


def _normalize_timing(text):
    return re.sub(r"in \d+\.\d+s \(\d+\.\d+ ms/config\)",
                  "in <T>s (<T> ms/config)", text)


def test_legacy_cli_identical_to_search_subcommand(capsys):
    rc_new = cli.main(["search"] + _CLI_ARGS)
    out_new = capsys.readouterr().out
    rc_old = cli.main(_CLI_ARGS)
    captured = capsys.readouterr()
    assert "deprecated" in captured.err
    assert rc_old == rc_new == 0
    assert _normalize_timing(captured.out) == _normalize_timing(out_new)


def test_cli_search_json(capsys):
    rc = cli.main(["search"] + _CLI_ARGS + ["--json"])
    out = capsys.readouterr().out
    assert rc == 0
    d = json.loads(out)
    assert d["schema_version"] == SCHEMA_VERSION
    assert SearchReport.from_json(out).best is not None


def test_cli_search_json_honors_save_launch(tmp_path, capsys):
    out = str(tmp_path / "launch.json")
    rc = cli.main(["search"] + _CLI_ARGS + ["--json", "--save-launch", out])
    assert rc == 0
    report = json.loads(capsys.readouterr().out)
    assert json.load(open(out)) == report["launch"]["raw"]


def test_cli_validation_exit_code(capsys):
    rc = cli.main(["search", "--model", "gpt-99", "--isl", "64",
                   "--osl", "16"])
    assert rc == cli.EXIT_USAGE
    assert "valid choices" in capsys.readouterr().err


def test_cli_list_json(capsys):
    rc = cli.main(["list", "--json"])
    assert rc == 0
    d = json.loads(capsys.readouterr().out)
    assert "repro-jax" in d["backends"]
    assert "tpu_v5e" in d["platforms"]


def test_cli_generate_from_report(tmp_path, capsys):
    rep_path = str(tmp_path / "report.json")
    rc = cli.main(["search"] + _CLI_ARGS + ["--save-report", rep_path])
    assert rc == 0
    capsys.readouterr()
    out_path = str(tmp_path / "launch.json")
    rc = cli.main(["generate", "--from-report", rep_path,
                   "--out", out_path, "--json"])
    assert rc == 0
    raw = json.loads(capsys.readouterr().out)
    assert raw["model"] == "llama3.1-8b"
    assert json.load(open(out_path)) == raw


# ---------------------------------------------------------------------------
# compare / speculative share the Configurator's engines
# ---------------------------------------------------------------------------

def test_compare_sweep():
    c = _small_configurator()
    comparison = c.compare([{"isl": 128, "osl": 32},
                            {"isl": 512, "osl": 64}],
                           labels=["short", "long"])
    assert isinstance(comparison, Comparison)
    assert len(comparison.reports) == 2
    assert comparison.reports[0].workload.isl == 128
    assert comparison.reports[1].workload.isl == 512
    # shared database across the sweep (one platform/backend pair)
    assert len(c._dbs) == 1
    assert "short" in comparison.summary()
    json.loads(comparison.to_json())


def test_speculative_on_best_config():
    c = _small_configurator()
    rep = c.search()
    best, sweep = c.speculative("internlm2-1.8b", acceptance=0.8,
                                report=rep)
    assert best.gamma >= 1
    assert len(sweep) == 8
    assert best.tpot_ms == min(p.tpot_ms for p in sweep)
