"""Pallas kernel validation (deliverable c): shape/dtype sweeps, interpret
mode on CPU, assert_allclose against the pure-jnp oracles in ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RTOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}
ATOL = {jnp.float32: 2e-5, jnp.bfloat16: 3e-2}


def _allclose(out, expect, dtype):
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        rtol=RTOL[dtype], atol=ATOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Sq,Sk,H,K,D,causal,window,bq,bk", [
    (2, 64, 64, 4, 2, 64, True, 0, 32, 32),
    (1, 128, 128, 8, 8, 64, True, 0, 64, 64),      # MHA
    (2, 48, 48, 4, 1, 32, True, 16, 16, 16),       # MQA + SWA
    (1, 100, 100, 4, 2, 64, True, 0, 32, 32),      # padding path
    (2, 64, 64, 4, 4, 128, False, 0, 32, 32),      # non-causal (encoder)
    (1, 96, 96, 6, 3, 64, True, 32, 48, 32),       # window spans blocks
])
def test_flash_attention(B, Sq, Sk, H, K, D, causal, window, bq, bk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D), dtype)
    k = jax.random.normal(ks[1], (B, Sk, K, D), dtype)
    v = jax.random.normal(ks[2], (B, Sk, K, D), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              block_q=bq, block_k=bk, interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    assert out.shape == q.shape and out.dtype == dtype
    _allclose(out, expect, dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,K,D,W,bk", [
    (4, 8, 2, 64, 128, 32),
    (2, 4, 4, 128, 64, 64),     # MHA
    (3, 8, 1, 64, 100, 32),     # MQA + non-multiple width
    (1, 16, 2, 64, 256, 128),
])
def test_decode_attention(B, H, K, D, W, bk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, H, D), dtype)
    kc = jax.random.normal(ks[1], (B, W, K, D), dtype)
    vc = jax.random.normal(ks[2], (B, W, K, D), dtype)
    vl = jnp.asarray(np.random.default_rng(0).integers(1, W + 1, B),
                     jnp.int32)
    out = ops.decode_attention(q, kc, vc, vl, block_k=bk, interpret=True)
    expect = ref.decode_attention_ref(q, kc, vc, vl)
    assert out.shape == (B, H, D)
    _allclose(out, expect, dtype)


@pytest.mark.parametrize("B,S,W,bs,bw", [
    (2, 64, 128, 16, 64),
    (3, 100, 96, 32, 32),     # non-multiples both dims
    (1, 17, 40, 8, 16),
    (4, 128, 256, 128, 128),
])
def test_rglru_scan(B, S, W, bs, bw):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, W)))
    b = jax.random.normal(ks[1], (B, S, W))
    h0 = jax.random.normal(ks[2], (B, W))
    out = ops.rglru_scan(a, b, h0, block_s=bs, block_w=bw, interpret=True)
    expect = ref.rglru_scan_ref(a, b, h0)
    _allclose(out, expect, jnp.float32)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("E,C,D,F", [
    (4, 64, 128, 256),
    (2, 100, 96, 130),       # ragged dims exercise padding
    (8, 32, 512, 64),
    (1, 128, 128, 128),
])
def test_moe_gemm(E, C, D, F, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 2)
    xe = (jax.random.normal(ks[0], (E, C, D), dtype) * 0.1).astype(dtype)
    we = (jax.random.normal(ks[1], (E, D, F), dtype) * 0.1).astype(dtype)
    out = ops.moe_gemm(xe, we, block_c=32, block_f=64, block_d=64,
                       interpret=True)
    expect = ref.moe_gemm_ref(xe, we)
    assert out.shape == (E, C, F)
    _allclose(out, expect, dtype)


def test_flash_matches_model_attention():
    """The kernel and the model's blockwise-jnp attention agree."""
    from repro.models import common as cm
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(ks[0], (2, 64, 8, 64))
    k = jax.random.normal(ks[1], (2, 64, 2, 64))
    v = jax.random.normal(ks[2], (2, 64, 2, 64))
    a = ops.flash_attention(q, k, v, causal=True, block_q=32, block_k=32,
                            interpret=True)
    b = cm.attention(q, k, v, None, causal=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)
