"""CLI surface of the observability layer: the ``obs export | diff``
subcommand, ``--trace-out``/``--metrics-out`` on the replay family
(workload replay, capacity sweep/plan, autoscale run/compare), the
``.chrome.json`` suffix routing, the flight-recorder sampling flags,
and the byte-identity of replay output with and without capture."""
import json

import pytest

from repro.core import cli
from repro.obs import TraceArtifact

_GEN = ["workload", "generate", "--arrivals", "poisson", "--rate", "4",
        "--n", "30", "--lengths", "fixed", "--isl", "64", "--osl", "8",
        "--seed", "3"]
_REPLAY = ["workload", "replay", "--model", "llama3.1-8b",
           "--tp", "1", "--batch", "8"]


@pytest.fixture()
def trace_path(tmp_path, capsys):
    path = str(tmp_path / "trace.jsonl")
    assert cli.main(_GEN + ["--out", path]) == 0
    capsys.readouterr()
    return path


def _replay(trace_path, capsys, *extra):
    rc = cli.main(_REPLAY + ["--trace", trace_path, "--json",
                             *extra])
    out = capsys.readouterr().out
    assert rc == 0
    return out


def test_replay_output_identical_with_and_without_capture(
        tmp_path, trace_path, capsys):
    plain = _replay(trace_path, capsys)
    captured = _replay(trace_path, capsys,
                       "--trace-out", str(tmp_path / "t.jsonl"),
                       "--metrics-out", str(tmp_path / "m.json"))
    assert plain == captured
    assert "histograms" not in json.loads(plain)["metrics"]


def test_replay_chrome_suffix_routing(tmp_path, trace_path, capsys):
    chrome = tmp_path / "t.chrome.json"
    _replay(trace_path, capsys, "--trace-out", str(chrome))
    ct = json.loads(chrome.read_text())
    events = [e for e in ct["traceEvents"] if e["ph"] == "X"]
    reqs = [e for e in events if e["name"] == "request"]
    assert len(reqs) == 30
    for e in events:
        assert {"name", "ph", "ts", "dur", "pid", "tid"} <= set(e)
        assert e["dur"] >= 0


def test_replay_metrics_out_carries_request_histograms(
        tmp_path, trace_path, capsys):
    out = tmp_path / "m.json"
    _replay(trace_path, capsys, "--metrics-out", str(out))
    snap = json.loads(out.read_text())
    assert "repro_request_ttft_ms{sim=serving}" in snap["histograms"]
    assert "repro_replay_slo_attainment{sim=serving}" in snap["gauges"]


def test_replay_sampling_flags(tmp_path, trace_path, capsys):
    chrome = tmp_path / "t.chrome.json"
    _replay(trace_path, capsys, "--trace-out", str(chrome),
            "--span-sample-every", "2", "--max-request-spans", "5")
    ct = json.loads(chrome.read_text())
    rids = [e["args"]["rid"] for e in ct["traceEvents"]
            if e.get("name") == "request"]
    assert rids == [0, 2, 4, 6, 8]
    # the knobs are restored after the command
    from repro.obs import flight_config
    assert flight_config().sample_every == 1
    assert flight_config().max_request_spans == 512


def test_capacity_sweep_capture(tmp_path, trace_path, capsys):
    chrome = tmp_path / "c.chrome.json"
    rc = cli.main(["capacity", "sweep", "--trace", trace_path,
                   "--model", "llama3.1-8b", "--tp", "1", "--batch", "8",
                   "--ladder", "1,2", "--json",
                   "--trace-out", str(chrome),
                   "--metrics-out", str(tmp_path / "c.json")])
    capsys.readouterr()
    assert rc == 0
    ct = json.loads(chrome.read_text())
    reqs = [e for e in ct["traceEvents"] if e.get("name") == "request"]
    assert reqs
    assert any("replica" in e["args"] for e in reqs)
    snap = json.loads((tmp_path / "c.json").read_text())
    assert "repro_request_e2e_ms{sim=cluster}" in snap["histograms"]


def test_autoscale_run_capture(tmp_path, trace_path, capsys):
    rc = cli.main(["autoscale", "run", "--trace", trace_path,
                   "--model", "llama3.1-8b", "--tp", "1", "--batch", "8",
                   "--policy", "target_queue_depth",
                   "--max-replicas", "2", "--json",
                   "--metrics-out", str(tmp_path / "a.json")])
    out = capsys.readouterr().out
    assert rc == 0
    summary = json.loads(out.strip().splitlines()[-1])
    assert "histograms" not in summary["metrics"]
    snap = json.loads((tmp_path / "a.json").read_text())
    assert "repro_request_e2e_ms{sim=autoscale}" in snap["histograms"]


def test_obs_export_chrome_matches_trace_out(tmp_path, trace_path,
                                             capsys):
    jsonl = tmp_path / "t.jsonl"
    chrome = tmp_path / "t.chrome.json"
    _replay(trace_path, capsys, "--trace-out", str(jsonl))
    _replay(trace_path, capsys, "--trace-out", str(chrome))
    exported = tmp_path / "exported.json"
    rc = cli.main(["obs", "export", "--trace", str(jsonl),
                   "--format", "chrome", "--out", str(exported)])
    capsys.readouterr()
    assert rc == 0
    assert exported.read_text() == chrome.read_text()


def test_obs_export_jsonl_roundtrip(tmp_path, trace_path, capsys):
    jsonl = tmp_path / "t.jsonl"
    _replay(trace_path, capsys, "--trace-out", str(jsonl))
    rc = cli.main(["obs", "export", "--trace", str(jsonl),
                   "--format", "jsonl", "--out", "-"])
    out = capsys.readouterr().out
    assert rc == 0
    assert TraceArtifact.from_jsonl(out).digest() \
        == TraceArtifact.load(str(jsonl)).digest()


def test_obs_diff_cli(tmp_path, trace_path, capsys):
    a, b = tmp_path / "a.json", tmp_path / "b.json"
    _replay(trace_path, capsys, "--metrics-out", str(a))
    rc = cli.main(_REPLAY[:-2] + ["--batch", "1", "--trace", trace_path,
                                  "--json", "--metrics-out", str(b)])
    capsys.readouterr()
    assert rc == 0
    assert cli.main(["obs", "diff", str(a), str(a)]) == 0
    assert "identical" in capsys.readouterr().out
    assert cli.main(["obs", "diff", str(a), str(b)]) == 1
    assert "repro_request_ttft_ms" in capsys.readouterr().out
    assert cli.main(["obs", "diff", str(a), str(b), "--json"]) == 1
    d = json.loads(capsys.readouterr().out)
    assert not d["identical"]
    assert d["slo_attainment"] is not None


def test_obs_diff_bad_input_is_usage_error(tmp_path, capsys):
    bad = tmp_path / "bad.json"
    bad.write_text('{"nonsense": true}')
    assert cli.main(["obs", "diff", str(bad), str(bad)]) == 2
    assert "error:" in capsys.readouterr().err


def test_obs_without_action_prints_help(capsys):
    assert cli.main(["obs"]) == 2
    capsys.readouterr()
