"""Dry-run CLI smoke: one (arch x shape) pair lowered + compiled on the real
16x16 production mesh in a subprocess (the 512-device XLA flag must be set
before jax init, so it cannot run in-process with the other tests)."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.parametrize("arch,shape", [("internlm2-1.8b", "decode_32k")])
def test_dryrun_single_pair(tmp_path, arch, shape):
    out = str(tmp_path / "dr.jsonl")
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", "single", "--out", out],
        env=env, capture_output=True, text=True, timeout=540)
    assert r.returncode == 0, r.stdout + r.stderr
    rec = json.loads(open(out).read().strip().splitlines()[-1])
    assert rec["ok"], rec.get("error")
    assert rec["flops_corrected"] > 0
    assert rec["mem"]["temp_size_in_bytes"] > 0
    assert rec["mesh"] == "16x16"


def test_input_specs_shapes():
    """input_specs builds ShapeDtypeStructs for every matrix pair without
    touching devices."""
    sys.path.insert(0, os.path.join(REPO, "src"))
    from repro.configs import dryrun_pairs, INPUT_SHAPES, get_config
    from repro.launch.dryrun import input_specs
    pairs = dryrun_pairs()
    assert len(pairs) == 34          # 10*4 minus six long_500k skips
    for arch, shape in pairs:
        specs = input_specs(arch, shape)
        sh = INPUT_SHAPES[shape]
        if sh.kind == "train":
            assert specs["tokens"].shape == (sh.global_batch, sh.seq_len)
        elif sh.kind == "prefill":
            assert specs["tokens"].shape == (sh.global_batch, sh.seq_len)
        else:
            assert specs["token"].shape == (sh.global_batch, 1)
            assert "cache" in specs


def test_long500k_skips_documented():
    from repro.configs import dryrun_pairs, get_config, list_archs
    pairs = set(dryrun_pairs())
    for arch in list_archs():
        cfg = get_config(arch)
        has_long = (arch, "long_500k") in pairs
        assert has_long == cfg.sub_quadratic
    # exactly the four sub-quadratic archs run long_500k
    longs = sorted(a for a, s in pairs if s == "long_500k")
    assert longs == ["h2o-danube-3-4b", "mixtral-8x22b",
                     "recurrentgemma-2b", "xlstm-350m"]
