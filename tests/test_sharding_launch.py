"""Sharding resolution + launch-layer units (no 512-device env needed:
meshes here are 1x1; the real 16x16 / 2x16x16 lowering is exercised by
the dry-run CLI, smoke-tested in test_dryrun_cli.py)."""
import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro import models
from repro.configs import get_config
from repro.launch import sharding as shd
from repro.launch.hlo_analysis import analyze
from repro.models.common import ParamSpec


def _fake_mesh(shape=(16, 16), axes=("data", "model")):
    """An axis-size carrier for spec resolution (no devices needed)."""
    class M:
        axis_names = axes
        class devices:
            pass
    m = M()
    m.devices = np.empty(shape, dtype=object)
    return m


def test_spec_divisibility_guard():
    lmap = {"ffn": "model", "embed": "data", "layers": None, None: None}
    sizes = {"data": 16, "model": 16}
    ps = ParamSpec((24, 2048, 8192), ("layers", "embed", "ffn"))
    assert shd.spec_for(ps, lmap, sizes) == P(None, "data", "model")
    # non-divisible dim falls back to replicated
    ps2 = ParamSpec((24, 100, 8192), ("layers", "embed", "ffn"))
    assert shd.spec_for(ps2, lmap, sizes) == P(None, None, "model")


def test_one_mesh_axis_used_once():
    lmap = {"experts": "model", "ffn": "model", "embed": None,
            "layers": None, None: None}
    sizes = {"model": 16}
    ps = ParamSpec((48, 128, 2048, 768), ("layers", "experts", "embed", "ffn"))
    spec = shd.spec_for(ps, lmap, sizes)
    assert spec == P(None, "model", None, None)   # experts win, ffn skipped


@pytest.mark.parametrize("arch", ["qwen3-14b", "qwen3-moe-30b-a3b",
                                  "mixtral-8x22b", "recurrentgemma-2b",
                                  "xlstm-350m", "whisper-small"])
def test_param_specs_cover_schema(arch):
    cfg = get_config(arch)
    mesh = _fake_mesh()
    specs = shd.param_specs(cfg, "train", mesh)
    sch = models.schema(cfg)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    flat_p = jax.tree.leaves(sch, is_leaf=lambda x: isinstance(x, ParamSpec))
    assert len(flat_s) == len(flat_p)
    sizes = {"data": 16, "model": 16}
    for spec, ps in zip(flat_s, flat_p):
        for dim, ax in zip(ps.shape, spec):
            if ax is None:
                continue
            n = np.prod([sizes[a] for a in (ax if isinstance(ax, tuple)
                                            else (ax,))])
            assert dim % n == 0, (arch, ps.shape, spec)


def test_moe_expert_parallel_vs_ffn_tp():
    mesh = _fake_mesh()
    qwen = get_config("qwen3-moe-30b-a3b")      # 128 experts -> EP
    mix = get_config("mixtral-8x22b")           # 8 experts -> ffn TP
    sq = shd.param_specs(qwen, "serve", mesh)["layers"]["we_gate"]
    sm = shd.param_specs(mix, "serve", mesh)["layers"]["we_gate"]
    assert sq[1] == "model" and sm[1] is None
    assert sm[3] == "model"


def test_serve_fsdp_threshold():
    """mixtral (282GB bf16) cannot replicate across data axis at serve."""
    mesh = _fake_mesh()
    mix = shd.logical_map(get_config("mixtral-8x22b"), "serve", mesh)
    small = shd.logical_map(get_config("internlm2-1.8b"), "serve", mesh)
    assert mix["embed"] == "data"
    assert small["embed"] is None


def test_kv_cache_spec_fallbacks():
    mesh = _fake_mesh()
    # K=8 not divisible by 16 but D=128 is -> head_dim sharding
    spec = shd.kv_cache_spec(get_config("qwen3-14b"), mesh, batch=128)
    assert spec == P(None, ("data",), None, None, "model")
    # danube: K=8, D=120 -> neither divides -> replicated kv dims
    spec2 = shd.kv_cache_spec(get_config("h2o-danube-3-4b"), mesh, batch=128)
    assert spec2 == P(None, ("data",), None, None, None)
    # batch=1 cannot shard
    spec3 = shd.kv_cache_spec(get_config("h2o-danube-3-4b"), mesh, batch=1)
    assert spec3[1] is None


def test_production_mesh_is_a_function():
    """Importing mesh.py must not touch device state; the factory exists."""
    from repro.launch import mesh as mesh_mod
    assert callable(mesh_mod.make_production_mesh)
    import inspect
    src = inspect.getsource(mesh_mod)
    assert "make_mesh" in src and "multi_pod" in src


def test_hlo_analyzer_trip_counts():
    import jax.numpy as jnp
    from jax import lax

    def f(x, w):
        def body(c, wi):
            return c @ wi, None
        y, _ = lax.scan(body, x, w)
        return y

    c = jax.jit(f).lower(jax.ShapeDtypeStruct((64, 64), jnp.float32),
                         jax.ShapeDtypeStruct((7, 64, 64), jnp.float32)
                         ).compile()
    st = analyze(c.as_text())
    assert st.flops == pytest.approx(7 * 2 * 64 ** 3, rel=1e-6)
    assert st.unresolved_loops == 0
