"""End-to-end regression tests for streaming incremental search:
drain-equivalence with batch ``search()``, early-exit policies pricing
strictly fewer candidates (PerfDatabase call-count probe), and the online
Pareto frontier matching the batch analyzer."""
import dataclasses

import pytest

from repro.api import (Configurator, SearchEvent, StreamingSearch, callback,
                       deadline_s, stop_after_n_valid)
from repro.core import pareto
from repro.core.config import ClusterSpec, SLA, WorkloadDescriptor
from repro.core.perf_database import PerfDatabase
from repro.core.task_runner import SearchProgress, TaskRunner


def _small_configurator(**kw):
    return (Configurator.for_model(kw.get("model", "llama3.1-8b"))
            .traffic(isl=kw.get("isl", 256), osl=kw.get("osl", 64))
            .sla(ttft_ms=2000, min_tokens_per_s_user=10)
            .cluster(chips=kw.get("chips", 8))
            .backend("repro-jax").dtype("fp8")
            .modes(*kw.get("modes", ("aggregated",))))


def _asdicts(projs):
    return [dataclasses.asdict(p) for p in projs]


# ---------------------------------------------------------------------------
# drain equivalence: streaming with no policy == batch search
# ---------------------------------------------------------------------------

def test_drained_search_iter_matches_search():
    c = _small_configurator()
    stream = c.search_iter()
    assert isinstance(stream, StreamingSearch)
    events = list(stream)
    assert events and all(isinstance(ev, SearchEvent) for ev in events)
    streamed = stream.report()
    batch = c.search()
    assert _asdicts(streamed.projections) == _asdicts(batch.projections)
    assert dataclasses.asdict(streamed.best) == dataclasses.asdict(batch.best)
    assert streamed.frontier_indices == batch.frontier_indices
    assert streamed.n_candidates == batch.n_candidates
    assert streamed.early_exit is None
    assert streamed.fingerprint == batch.fingerprint
    # events carried the same projections, in pricing order
    assert _asdicts([ev.projection for ev in events]) \
        == _asdicts(batch.projections)


def test_drained_stream_matches_legacy_taskrunner():
    w = WorkloadDescriptor(
        model="llama3.1-8b", isl=256, osl=64,
        sla=SLA(ttft_ms=2000, min_tokens_per_s_user=10),
        cluster=ClusterSpec(n_chips=8), backend="repro-jax", dtype="fp8",
        modes=("aggregated",))
    legacy = TaskRunner(w, PerfDatabase("tpu_v5e", "repro-jax")).run()
    stream = _small_configurator().search_iter()
    for _ in stream:
        pass
    result = stream.result()
    assert _asdicts(result.projections) == _asdicts(legacy.projections)
    assert dataclasses.asdict(result.best) == dataclasses.asdict(legacy.best)
    assert _asdicts(result.frontier) == _asdicts(legacy.frontier)
    assert result.n_candidates == legacy.n_candidates


@pytest.mark.slow
def test_drain_equivalence_with_disagg_modes():
    c = _small_configurator(isl=128, osl=32, chips=4,
                            modes=("aggregated", "disaggregated"))
    stream = c.search_iter()
    events = list(stream)
    streamed = stream.report()
    batch = c.search()
    assert _asdicts(streamed.projections) == _asdicts(batch.projections)
    assert streamed.n_candidates == batch.n_candidates
    assert streamed.disagg == batch.disagg
    assert any(ev.projection.mode == "disaggregated" for ev in events)


# ---------------------------------------------------------------------------
# early-exit policies
# ---------------------------------------------------------------------------

def test_stop_after_n_valid_prices_strictly_fewer_candidates():
    # full sweep on a fresh database: the call-count probe baseline
    c_full = _small_configurator()
    full_report = c_full.search()
    full_queries = c_full.database().stats.seq_queries
    assert full_report.best is not None
    n_valid_total = sum(p.meets(full_report.workload.sla)
                        for p in full_report.projections)
    assert n_valid_total > 3   # early exit below must leave work unpriced

    # early exit on its own fresh database
    c_early = _small_configurator()
    stream = c_early.search_iter(policies=[stop_after_n_valid(3)])
    events = list(stream)
    early_queries = c_early.database().stats.seq_queries

    assert sum(ev.meets_sla for ev in events) == 3
    assert stream.n_valid == 3
    assert events[-1].meets_sla            # the 3rd valid one stopped it
    report = stream.report()
    assert report.early_exit is not None
    assert report.early_exit["reason"] == "stop_after_n_valid(3)"
    assert report.n_candidates < full_report.n_candidates
    assert early_queries < full_queries    # PerfDatabase call-count probe
    # the partial report is still a coherent artifact
    assert report.best is not None and report.best.meets(report.workload.sla)
    assert report.frontier


def test_deadline_policy_stops_stream():
    stream = _small_configurator().search_iter(policies=[deadline_s(1e-9)])
    events = list(stream)
    assert len(events) == 1                # first yield trips the deadline
    report = stream.report(generate_launch=False)
    assert report.early_exit["reason"].startswith("deadline_s")
    assert len(report.projections) == 1


def test_deadline_preempts_disaggregated_mid_match():
    """The disaggregated phase prices its whole pool grid before the first
    composite yields; deadline_s must preempt it out-of-band (the
    check_elapsed hook threaded through SearchProgress.abort), not wait
    for a yield that may never come."""
    full = _small_configurator(modes=("disaggregated",)) \
        .search(generate_launch=False)
    assert full.n_candidates > 0 and full.early_exit is None

    # a deadline this short has always elapsed by the first out-of-band
    # check, so preemption deterministically lands in pool pricing
    c = _small_configurator(modes=("disaggregated",))
    stream = c.search_iter(policies=[deadline_s(1e-7)])
    list(stream)
    report = stream.report(generate_launch=False)
    assert report.early_exit is not None
    assert report.early_exit["reason"].startswith("deadline_s")
    assert report.early_exit["phase"] == "disaggregated"
    # strictly fewer pool candidates priced than the full match
    assert report.n_candidates < full.n_candidates


def test_disagg_pool_pricing_reports_progress():
    w = WorkloadDescriptor(
        model="llama3.1-8b", isl=256, osl=64,
        sla=SLA(ttft_ms=2000, min_tokens_per_s_user=10),
        cluster=ClusterSpec(n_chips=8), backend="repro-jax", dtype="fp8",
        modes=("disaggregated",))
    runner = TaskRunner(w, PerfDatabase("tpu_v5e", "repro-jax"))
    progress = SearchProgress()
    list(runner.iter_search(progress=progress))
    assert progress.disagg_done and not progress.disagg_preempted
    assert progress.disagg_pool_evaluated > 0
    assert progress.n_evaluated == progress.disagg_pool_evaluated


def test_callback_policy_sees_every_event_and_can_stop():
    seen = []

    def hook(ev):
        seen.append(ev)
        return len(seen) >= 5

    stream = _small_configurator().search_iter(policies=[callback(hook)])
    events = list(stream)
    assert events == seen
    assert len(events) == 5
    assert stream.early_exit["reason"] == "callback(hook)"


def test_policy_validation():
    with pytest.raises(ValueError):
        stop_after_n_valid(0)
    with pytest.raises(ValueError):
        deadline_s(0)


def test_closed_stream_skips_remaining_pricing():
    c = _small_configurator()
    progress_probe = c.database().stats
    stream = c.search_iter()
    first = next(stream)
    queries_after_one = progress_probe.seq_queries
    stream.close()     # explicit abandon (e.g. after `break` in a UI loop)
    stream.close()     # idempotent
    assert progress_probe.seq_queries == queries_after_one
    assert first.index == 0 and stream.n_priced >= 1
    with pytest.raises(StopIteration):
        next(stream)
    # a closed stream still materializes a coherent partial report
    assert len(stream.report(generate_launch=False).projections) == 1


def test_search_accepts_policies_directly():
    # the facade's batch entry point takes the same policies the CLI's
    # --first-n uses: no manual drain loop needed for early exit
    c = _small_configurator()
    report = c.search(policies=[stop_after_n_valid(2)])
    assert report.early_exit["reason"] == "stop_after_n_valid(2)"
    assert sum(p.meets(report.workload.sla) for p in report.projections) == 2


def test_deadline_policy_object_is_reusable_across_searches():
    policy = deadline_s(30.0)   # generous: neither search should trip it
    c = _small_configurator()
    first = list(c.search_iter(policies=[policy]))
    second_stream = c.search_iter(policies=[policy])
    second = list(second_stream)
    # the anchor re-arms per stream, so the (warm, fast) second search
    # must run to completion instead of inheriting the first one's clock
    assert len(second) == len(first)
    assert second_stream.early_exit is None


# ---------------------------------------------------------------------------
# online frontier == batch analyzer, live views
# ---------------------------------------------------------------------------

def test_stream_frontier_matches_batch_analyzer():
    stream = _small_configurator().search_iter()
    running = []
    for ev in stream:
        running.append(ev.projection)
        assert ev.frontier_size == len(pareto.frontier(running))
    assert _asdicts(stream.frontier) == _asdicts(pareto.frontier(running))
    assert dataclasses.asdict(stream.best) \
        == dataclasses.asdict(pareto.best(running, stream.workload.sla))


def test_core_iter_search_reports_progress():
    w = WorkloadDescriptor(
        model="llama3.1-8b", isl=256, osl=64,
        sla=SLA(ttft_ms=2000, min_tokens_per_s_user=10),
        cluster=ClusterSpec(n_chips=8), backend="repro-jax", dtype="fp8",
        modes=("aggregated",))
    runner = TaskRunner(w, PerfDatabase("tpu_v5e", "repro-jax"))
    progress = SearchProgress()
    pairs = list(runner.iter_search(progress=progress))
    assert progress.n_yielded == len(pairs)
    # every enumerated candidate was priced exactly once (aggregated only)
    assert progress.n_evaluated == len(runner.candidates())
    for cand, proj in pairs:
        assert proj.batch_size == cand.batch_size
