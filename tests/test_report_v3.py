"""SearchReport schema v3: the ``workload_eval`` section (trace replay +
SLO re-ranking) round-trips, and both v1 and v2 golden fixtures still
migrate losslessly."""
import json
import os

import pytest

from repro.api import (Configurator, SCHEMA_VERSION,
                       SUPPORTED_SCHEMA_VERSIONS, SearchReport)
from repro.workloads import (ArrivalSpec, LengthSpec, SLOSpec, TenantSpec,
                             TraceSpec, generate_trace)

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures")
V1_FIXTURE = os.path.join(FIXTURES, "search_report_v1.json")
V2_FIXTURE = os.path.join(FIXTURES, "search_report_v2.json")


def _small_configurator():
    return (Configurator.for_model("llama3.1-8b")
            .traffic(isl=256, osl=64)
            .sla(ttft_ms=2000, min_tokens_per_s_user=10)
            .cluster(chips=8).backend("repro-jax").dtype("fp8")
            .modes("aggregated"))


def _small_trace(seed=3):
    return generate_trace(TraceSpec(
        n_requests=40,
        arrivals=ArrivalSpec(kind="bursty", rate_rps=6.0),
        tenants=(TenantSpec(name="chat", weight=0.7, priority=1,
                            lengths=LengthSpec(kind="lognormal",
                                               isl=256, osl=64)),
                 TenantSpec(name="batch", weight=0.3,
                            lengths=LengthSpec(kind="lognormal",
                                               isl=512, osl=96)))),
        seed=seed)


@pytest.fixture(scope="module")
def evaluated():
    cfg = _small_configurator()
    return cfg.evaluate_frontier(_small_trace(),
                                 SLOSpec(ttft_p99_ms=1500, tpot_p99_ms=60),
                                 top_k=3)


# ---------------------------------------------------------------------------
# the v3 workload_eval section
# ---------------------------------------------------------------------------

def test_schema_versions_supported():
    assert SCHEMA_VERSION == 7
    assert set(SUPPORTED_SCHEMA_VERSIONS) == {1, 2, 3, 4, 5, 6, 7}


def test_workload_eval_section_structure(evaluated):
    we = evaluated.workload_eval
    assert we is not None
    assert set(we) >= {"trace", "slo", "candidates", "ranking",
                       "analytical_ranking", "best_index", "reranked"}
    assert we["slo"] == {"ttft_p99_ms": 1500, "tpot_p99_ms": 60}
    assert we["trace"]["n_requests"] == 40
    assert len(we["trace"]["digest"]) == 16
    # replayed entries carry the full open-loop metric set
    replayed = [c for c in we["candidates"] if c["replay"] is not None]
    assert replayed
    for c in replayed:
        r = c["replay"]
        assert set(r["ttft_ms"]) == {"p50", "p95", "p99"}
        assert r["ttft_ms"]["p50"] <= r["ttft_ms"]["p99"]
        assert r["goodput_tok_s"] <= r["throughput_tok_s"] + 1e-9
        assert 0.0 <= r["slo_attainment"] <= 1.0
        assert r["completed"] + r["rejected"] + r["unfinished"] \
            == r["n_requests"]
    # rankings index into report.projections
    for idx in we["ranking"]:
        assert 0 <= idx < len(evaluated.projections)
    assert sorted(we["ranking"]) == sorted(we["analytical_ranking"])
    assert we["best_index"] == we["ranking"][0]


def test_v3_roundtrip_preserves_workload_eval(evaluated):
    blob = evaluated.to_json()
    assert json.loads(blob)["schema_version"] == SCHEMA_VERSION
    back = SearchReport.from_json(blob)
    assert back == evaluated
    assert back.workload_eval == evaluated.workload_eval
    assert back.to_json() == blob            # byte-stable second hop


def test_summary_mentions_workload_replay(evaluated):
    text = evaluated.summary()
    assert "workload replay" in text
    assert evaluated.workload_eval["trace"]["digest"] in text


def test_evaluate_frontier_reuses_supplied_report(evaluated):
    cfg = _small_configurator()
    report = cfg.search(generate_launch=False)
    n_before = report.n_candidates
    out = cfg.evaluate_frontier(_small_trace(),
                                SLOSpec(ttft_p99_ms=1500, tpot_p99_ms=60),
                                top_k=2, report=report)
    assert out is report                     # filled in place
    assert report.n_candidates == n_before   # no re-search
    assert report.workload_eval["top_k"] == 2


def test_zero_signal_replay_keeps_analytical_order(evaluated):
    """When nothing attains the SLO every goodput is 0; ties must fall
    back to the analytical order, so reranked stays False."""
    cfg = _small_configurator()
    report = cfg.search(generate_launch=False)
    out = cfg.evaluate_frontier(
        _small_trace(), SLOSpec(ttft_p99_ms=1e-6, tpot_p99_ms=1e-6),
        top_k=3, report=report)
    we = out.workload_eval
    replayed = [c for c in we["candidates"] if c["replay"] is not None]
    assert all(c["replay"]["goodput_tok_s"] == 0.0 for c in replayed)
    assert we["ranking"] == we["analytical_ranking"]
    assert we["reranked"] is False


def test_workload_eval_records_replay_database(evaluated):
    """The replay pricing identity is auditable next to the search's."""
    we = evaluated.workload_eval
    assert we["database"]["platform"] == "tpu_v5e"
    assert we["database"]["backend"] == "repro-jax"
    # same (platform, backend) pair that priced the analytical search;
    # grid_hash may differ (replay collects extra grids lazily)
    assert we["database"]["platform"] == evaluated.fingerprint["platform"]
    assert we["database"]["backend"] == evaluated.fingerprint["backend"]
    assert len(we["database"]["grid_hash"]) == 16


# ---------------------------------------------------------------------------
# golden fixtures: v1 and v2 still read losslessly
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("path,version", [(V1_FIXTURE, 1), (V2_FIXTURE, 2)])
def test_golden_fixture_migrates(path, version):
    with open(path) as f:
        payload = json.load(f)
    assert payload["schema_version"] == version
    rep = SearchReport.load(path)
    assert rep.schema_version == SCHEMA_VERSION
    # shared-by-all-versions fields survive byte-exact
    assert rep.n_candidates == payload["search"]["n_candidates"]
    assert rep.elapsed_s == payload["search"]["elapsed_s"]
    assert rep.frontier_indices == payload["frontier"]
    assert rep.best_index == payload["best"]
    assert len(rep.projections) == len(payload["projections"])
    for proj, raw in zip(rep.projections, payload["projections"]):
        assert proj.tokens_per_s_per_chip == raw["tokens_per_s_per_chip"]
        assert proj.config == raw["config"]
    # sections the version never carried default to None
    assert rep.workload_eval is None
    if version == 1:
        assert rep.fingerprint is None and rep.early_exit is None


def test_v2_golden_fixture_keeps_v2_sections():
    with open(V2_FIXTURE) as f:
        payload = json.load(f)
    rep = SearchReport.load(V2_FIXTURE)
    assert rep.fingerprint == payload["database"]
    assert rep.early_exit == payload["search"]["early_exit"]
    assert rep.early_exit is not None        # fixture recorded an early exit
    # and it re-serializes as the current version with workload_eval
    # defaulting to null
    d = rep.to_dict()
    assert d["schema_version"] == SCHEMA_VERSION
    assert d["workload_eval"] is None
    assert SearchReport.from_json(rep.to_json()) == rep
