"""CLI ``capacity plan|sweep``: per-rung JSON-lines records, min-chip
summary, digest-stable output across runs, and stable exit codes."""
import json

import pytest

from repro.core import cli

_TRACE_ARGS = ["workload", "generate", "--arrivals", "bursty", "--rate",
               "60", "--burst-factor", "4", "--n", "60", "--lengths",
               "lognormal", "--isl", "256", "--osl", "64", "--tenants",
               "chat:0.7:1,batch:0.3", "--seed", "7"]

_SWEEP_ARGS = ["--model", "llama3.1-8b", "--tp", "1", "--batch", "64",
               "--dtype", "fp8", "--ladder", "1,2,4",
               "--slo-ttft-p99", "400", "--slo-tpot-p99", "50"]


@pytest.fixture(scope="module")
def trace_path(tmp_path_factory):
    path = str(tmp_path_factory.mktemp("cap") / "trace.jsonl")
    assert cli.main(_TRACE_ARGS + ["--out", path]) == 0
    return path


def _records(capsys):
    lines = capsys.readouterr().out.strip().splitlines()
    return [json.loads(line) for line in lines if line.strip()]


def test_capacity_sweep_json_emits_rungs_and_plan(trace_path, capsys):
    rc = cli.main(["capacity", "sweep", "--trace", trace_path]
                  + _SWEEP_ARGS + ["--json"])
    records = _records(capsys)
    assert rc == 0
    rungs, summary = records[:-1], records[-1]
    assert all(r["type"] == "rung" for r in rungs)
    assert summary["type"] == "summary"
    # the seeded scenario: 1 replica misses, 2 attains, rung 4 early-stopped
    by_replicas = {r["replicas"]: r for r in rungs}
    assert by_replicas[1]["attains"] is False
    assert by_replicas[2]["attains"] is True
    assert 4 not in by_replicas
    plan = summary["plan"]
    assert plan["total_chips"] == 2
    assert plan["slo_attainment"] >= summary["attain_target"]
    assert by_replicas[2]["imbalance"]["routed_max_over_mean"] >= 1.0


def test_capacity_sweep_json_digest_stable_across_runs(trace_path, capsys):
    rc1 = cli.main(["capacity", "sweep", "--trace", trace_path]
                   + _SWEEP_ARGS + ["--json"])
    out1 = capsys.readouterr().out
    rc2 = cli.main(["capacity", "sweep", "--trace", trace_path]
                   + _SWEEP_ARGS + ["--json"])
    out2 = capsys.readouterr().out
    assert rc1 == rc2 == 0
    assert out1 == out2                      # byte-identical, not merely close


def test_capacity_sweep_human_output(trace_path, capsys):
    rc = cli.main(["capacity", "sweep", "--trace", trace_path]
                  + _SWEEP_ARGS)
    out = capsys.readouterr().out
    assert rc == 0
    assert "min-chip plan" in out
    assert "ATTAINS" in out and "misses SLO" in out


def test_capacity_sweep_unattainable_exits_1(trace_path, capsys):
    rc = cli.main(["capacity", "sweep", "--trace", trace_path,
                   "--model", "llama3.1-8b", "--ladder", "1,2",
                   "--slo-ttft-p99", "0.001", "--slo-tpot-p99", "0.001",
                   "--json"])
    records = _records(capsys)
    assert rc == 1
    assert records[-1]["plan"] is None


def test_capacity_sweep_bad_inputs_exit_2(trace_path, capsys):
    assert cli.main(["capacity", "sweep", "--trace", "/nonexistent.jsonl",
                     "--model", "llama3.1-8b"]) == 2
    capsys.readouterr()
    assert cli.main(["capacity", "sweep", "--trace", trace_path,
                     "--model", "llama3.1-8b", "--ladder", "4,2,1"]) == 2
    assert "ascending" in capsys.readouterr().err


def test_capacity_plan_json_schema_v4_report(trace_path, capsys, tmp_path):
    saved = str(tmp_path / "report.json")
    rc = cli.main(["capacity", "plan", "--model", "llama3.1-8b",
                   "--isl", "256", "--osl", "64", "--ttft", "2000",
                   "--min-speed", "10", "--chips", "8", "--dtype", "fp8",
                   "--modes", "aggregated", "--trace", trace_path,
                   "--ladder", "1,2,4", "--top-k", "2",
                   "--slo-ttft-p99", "400", "--slo-tpot-p99", "50",
                   "--save-report", saved, "--json"])
    report = json.loads(capsys.readouterr().out)
    assert rc == 0
    from repro.api import SCHEMA_VERSION
    assert report["schema_version"] == SCHEMA_VERSION
    cap = report["capacity"]
    assert cap["plan"]["attained"] is True
    assert cap["plan"]["total_chips"] is not None
    assert len(cap["candidates"]) >= 1
    assert json.load(open(saved))["capacity"] == cap


def test_capacity_plan_human_output(trace_path, capsys):
    rc = cli.main(["capacity", "plan", "--model", "llama3.1-8b",
                   "--isl", "256", "--osl", "64", "--ttft", "2000",
                   "--min-speed", "10", "--chips", "8", "--dtype", "fp8",
                   "--modes", "aggregated", "--trace", trace_path,
                   "--slo-ttft-p99", "400", "--slo-tpot-p99", "50"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "capacity plan" in out
    assert "ladder" in out
