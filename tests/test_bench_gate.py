"""Two-tier bench gate: hard counter gates (property: identical
snapshots never flag), the soft wallclock comparator (property: never
flags within tolerance, always flags beyond, tolerance monotonicity),
and environment-fingerprint refusal."""
import dataclasses

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, st

from repro.obs.bench import (BenchArtifact, BenchRecord, BenchTiming,
                             EnvironmentMismatch, compare_artifacts,
                             diff_environment, format_compare,
                             gate_artifacts, history_entry, soft_exceeds,
                             trend_summary)

ENV = {"platform": "test-host", "python": "3.11.0",
       "repro": {"REPRO_PRICING_CHUNK": 64}}


def _art(counters_by_bench, min_us_by_bench=None, env=None, status=None):
    """Build an artifact from ``{bench: {counter: value}}`` (+ optional
    per-bench min-of-k wallclock and statuses)."""
    min_us_by_bench = min_us_by_bench or {}
    status = status or {}
    records = [
        BenchRecord(
            name=name, status=status.get(name, "ok"),
            timing=BenchTiming.from_samples(
                [float(min_us_by_bench.get(name, 1000.0))]),
            counters={k: float(v) for k, v in counters.items()},
            phases={}, error="boom" if status.get(name) == "error" else "")
        for name, counters in sorted(counters_by_bench.items())]
    return BenchArtifact(suite="quick", created_at="2026-01-01T00:00:00Z",
                         environment=ENV if env is None else env,
                         records=records)


# ---------------------------------------------------------------------------
# hard tier — properties
# ---------------------------------------------------------------------------

@settings(max_examples=50)
@given(st.lists(st.integers(0, 10_000), min_size=1, max_size=8),
       st.floats(1.0, 1e7))
def test_hard_identical_snapshots_never_flag(values, min_us):
    """Gating any artifact against itself (same counters, any
    wallclock) never produces a violation of either tier."""
    counters = {f"repro_work_{i}_total": v for i, v in enumerate(values)}
    art = _art({"bench_a": counters}, {"bench_a": min_us})
    res = gate_artifacts(art, art)
    assert res.ok
    assert res.hard_violations == []
    assert res.soft_violations == []
    assert res.improvements == []


@settings(max_examples=50)
@given(st.integers(0, 10_000), st.integers(1, 10_000))
def test_hard_growth_always_flags(base, delta):
    baseline = _art({"b": {"repro_work_total": base}})
    current = _art({"b": {"repro_work_total": base + delta}})
    res = gate_artifacts(baseline, current)
    assert not res.ok
    v, = res.hard_violations
    assert v["kind"] == "grew" and v["bench"] == "b"
    assert v["baseline"] == base and v["current"] == base + delta


def test_hard_shrink_is_improvement_not_violation():
    res = gate_artifacts(_art({"b": {"w": 10}}), _art({"b": {"w": 4}}))
    assert res.ok
    assert res.improvements == [
        {"bench": "b", "counter": "w", "baseline": 10.0, "current": 4.0}]


def test_hard_appeared_and_vanished_counters_flag():
    res = gate_artifacts(_art({"b": {"w": 1, "gone": 2}}),
                         _art({"b": {"w": 1, "new": 3}}))
    kinds = {(v["counter"], v["kind"]) for v in res.hard_violations}
    assert kinds == {("new", "appeared"), ("gone", "vanished")}


def test_errored_records_are_skipped_not_gated():
    baseline = _art({"b": {"w": 1}}, status={"b": "error"})
    current = _art({"b": {"w": 999}})
    res = gate_artifacts(baseline, current)
    assert res.ok and res.errored == ["b"]


def test_subset_run_gates_against_shared_records_only():
    """A --only run gates against the full committed baseline: shared
    benches are gated, the rest are reported as uncovered/new."""
    baseline = _art({"a": {"w": 1}, "b": {"w": 2}})
    current = _art({"b": {"w": 2}, "c": {"w": 3}})
    res = gate_artifacts(baseline, current)
    assert res.ok
    assert res.uncovered == ["a"] and res.new_benches == ["c"]


# ---------------------------------------------------------------------------
# soft tier — properties on the pure predicate and through the gate
# ---------------------------------------------------------------------------

@settings(max_examples=100)
@given(st.floats(1.0, 1e6), st.floats(0.0, 1.0), st.floats(0.0, 2.0))
def test_soft_never_flags_within_tolerance(base_us, frac, rel_tol):
    """cur <= base*(1+rel_tol) (reached via frac of the allowance) is
    never flagged, at any tolerance — through the full gate."""
    cur_us = base_us * (1.0 + frac * rel_tol)
    assert not soft_exceeds(base_us, cur_us, rel_tol, abs_tol_us=0.0)
    res = gate_artifacts(_art({"b": {}}, {"b": base_us}),
                         _art({"b": {}}, {"b": cur_us}),
                         rel_tol=rel_tol, abs_tol_us=0.0)
    assert res.soft_violations == [] and res.ok


@settings(max_examples=100)
@given(st.floats(1.0, 1e6), st.floats(1e-6, 1.0), st.floats(0.0, 2.0),
       st.floats(0.0, 5000.0))
def test_soft_always_flags_beyond_tolerance(base_us, eps, rel_tol,
                                            abs_tol_us):
    """Anything strictly beyond base*(1+rel_tol)+abs_tol is flagged."""
    threshold = base_us * (1.0 + rel_tol) + abs_tol_us
    cur_us = threshold * (1.0 + eps) + eps
    assert soft_exceeds(base_us, cur_us, rel_tol, abs_tol_us)
    res = gate_artifacts(_art({"b": {}}, {"b": base_us}),
                         _art({"b": {}}, {"b": cur_us}),
                         rel_tol=rel_tol, abs_tol_us=abs_tol_us)
    assert len(res.soft_violations) == 1 and not res.ok


@settings(max_examples=100)
@given(st.floats(1.0, 1e6), st.floats(1.0, 5e6),
       st.floats(0.0, 1.0), st.floats(0.0, 1.0))
def test_soft_tolerance_boundary_monotonicity(base_us, cur_us, tol_a, tol_b):
    """Flagging is antitone in the tolerance: flagged at the looser
    tolerance implies flagged at the tighter one."""
    lo, hi = sorted((tol_a, tol_b))
    if soft_exceeds(base_us, cur_us, hi, abs_tol_us=0.0):
        assert soft_exceeds(base_us, cur_us, lo, abs_tol_us=0.0)


@settings(max_examples=100)
@given(st.floats(1.0, 1e6), st.floats(1.0, 5e6), st.floats(1.0, 5e6),
       st.floats(0.0, 1.0))
def test_soft_monotone_in_current(base_us, cur_a, cur_b, rel_tol):
    """Flagging is monotone in the current time: if a faster run flags,
    every slower run flags too."""
    lo, hi = sorted((cur_a, cur_b))
    if soft_exceeds(base_us, lo, rel_tol):
        assert soft_exceeds(base_us, hi, rel_tol)


def test_hard_only_skips_soft_tier():
    res = gate_artifacts(_art({"b": {}}, {"b": 100.0}),
                         _art({"b": {}}, {"b": 1e9}), hard_only=True)
    assert res.ok and res.soft_skipped == "--hard-only"


# ---------------------------------------------------------------------------
# environment fingerprints
# ---------------------------------------------------------------------------

def _other_env():
    return {"platform": "test-host", "python": "3.11.0",
            "repro": {"REPRO_PRICING_CHUNK": 1}}


def test_diff_environment_flattens_nested_keys():
    delta = diff_environment(ENV, _other_env())
    assert delta == {"repro.REPRO_PRICING_CHUNK": (64, 1)}


def test_compare_refuses_mismatched_environments():
    a = _art({"b": {"w": 1}})
    b = _art({"b": {"w": 1}}, env=_other_env())
    with pytest.raises(EnvironmentMismatch,
                       match="REPRO_PRICING_CHUNK"):
        compare_artifacts(a, b)


def test_gate_env_mismatch_skips_soft_but_keeps_hard():
    """The CI injection scenario: a REPRO_* knob changes the
    fingerprint AND inflates a work counter — the soft tier is skipped
    with a reason, the hard tier still fails the gate."""
    baseline = _art({"b": {"repro_search_chunks_total": 2}}, {"b": 100.0})
    current = _art({"b": {"repro_search_chunks_total": 90}}, {"b": 1e9},
                   env=_other_env())
    res = gate_artifacts(baseline, current)
    assert not res.ok
    assert res.soft_violations == []
    assert "REPRO_PRICING_CHUNK" in res.soft_skipped
    assert res.hard_violations[0]["counter"] == "repro_search_chunks_total"


# ---------------------------------------------------------------------------
# compare + trend
# ---------------------------------------------------------------------------

def test_compare_identical_and_drifted():
    a = _art({"b": {"w": 1}})
    assert compare_artifacts(a, a)["identical"]
    drift = compare_artifacts(a, _art({"b": {"w": 2}}))
    assert not drift["identical"]
    assert drift["records"]["b"]["counters"]["changed"] == {"w": (1.0, 2.0)}
    assert "w  1 -> 2" in format_compare(drift)


def test_compare_reports_record_set_drift():
    cmp = compare_artifacts(_art({"a": {}, "b": {}}), _art({"b": {}}))
    assert not cmp["identical"]
    assert cmp["only_a"] == ["a"] and cmp["only_b"] == []


def test_trend_counts_work_changes_not_wallclock():
    arts = [_art({"b": {"w": 1}}, {"b": 100.0}),
            _art({"b": {"w": 1}}, {"b": 900.0}),
            _art({"b": {"w": 5}}, {"b": 50.0})]
    summary = trend_summary([history_entry(a) for a in arts])
    t = summary["benches"]["b"]
    assert t["runs"] == 3
    assert t["work_changes"] == 1
    assert t["best_min_us"] == 50.0
    assert t["first_median_us"] == 100.0 and t["last_median_us"] == 50.0


def test_trend_filters_by_suite_and_skips_errors():
    ok = history_entry(_art({"b": {"w": 1}}))
    err = history_entry(_art({"b": {"w": 1}}, status={"b": "error"}))
    summary = trend_summary([ok, err])
    assert summary["benches"]["b"]["runs"] == 1
    assert trend_summary([ok], suite="full")["benches"] == {}
