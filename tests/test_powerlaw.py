"""Property tests (hypothesis) for the power-law MoE load correction
(§4.4.1, eq. 3–4)."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare environment: deterministic fallback shim
    from _hypothesis_compat import given, settings, st

from repro.core import powerlaw


@given(st.integers(2, 256), st.floats(0.01, 2.0), st.integers(0, 10_000))
@settings(max_examples=60, deadline=None)
def test_weights_within_bounds(E, alpha, seed):
    rng = np.random.default_rng(seed)
    x = powerlaw.sample_weights(E, alpha, rng)
    assert x.shape == (E,)
    assert np.all(x >= powerlaw.X_MIN - 1e-9)
    assert np.all(x <= powerlaw.X_MAX + 1e-9)


@given(st.integers(1, 4096), st.integers(1, 8), st.integers(2, 128),
       st.floats(0.01, 1.5), st.integers(0, 1000))
@settings(max_examples=60, deadline=None)
def test_token_counts_conserved(T, K, E, alpha, seed):
    """Eq. 4: Σ N_i == T_total * K exactly (residual rebalancing)."""
    n = powerlaw.token_counts(T, K, E, alpha, seed)
    assert n.sum() == T * K
    assert np.all(n >= 0)


def test_alpha_controls_skew():
    """Fig. 5: larger alpha -> heavier tail (hot experts hold more)."""
    T, K, E = 8192, 8, 128
    def top20_share(alpha):
        shares = []
        for seed in range(20):
            n = powerlaw.token_counts(T, K, E, alpha, seed)
            n = np.sort(n)[::-1]
            shares.append(n[:E // 5].sum() / n.sum())
        return np.mean(shares)
    uniform_ish = top20_share(0.05)
    skewed = top20_share(1.2)
    assert skewed > uniform_ish + 0.1
    # paper: alpha≈1.2 -> ~70% of compute on 20% of experts
    assert 0.45 < skewed < 0.95


@given(st.integers(4, 512), st.integers(2, 64), st.integers(0, 100))
@settings(max_examples=40, deadline=None)
def test_assignment_matrix_column_sums(T, E, seed):
    counts = powerlaw.token_counts(T, 2, E, 1.0, seed)
    L = powerlaw.assignment_matrix(T, counts)
    assert L.shape == (T, E)
    np.testing.assert_array_equal(L.sum(axis=0), counts)


@given(st.integers(2, 64), st.integers(0, 50))
@settings(max_examples=30, deadline=None)
def test_hot_rank_bounds(ep, seed):
    """Hottest rank holds between mean-share and everything."""
    T, K, E = 4096, 8, 128
    ep = min(ep, E)
    hot = powerlaw.hot_rank_tokens(T, K, E, ep, 1.2, seed)
    total = T * K
    assert total / ep - 1 <= hot <= total


def test_hot_rank_monotone_in_alpha():
    vals = [np.mean([powerlaw.hot_rank_tokens(4096, 8, 128, 16, a, s)
                     for s in range(30)]) for a in (0.05, 1.2)]
    assert vals[1] > vals[0]
