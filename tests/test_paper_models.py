"""The paper's own evaluation models (perf-model-only in the dry-run
matrix) are nonetheless REAL model configs: their reduced variants run a
forward pass too, including DeepSeek's MLA-adjacent MoE with shared
experts."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro import models
from repro.configs import get_config, list_archs
from repro.core.config import ClusterSpec, SLA, WorkloadDescriptor
from repro.core.perf_database import PerfDatabase
from repro.core.session import InferenceSession
from repro.core.task_runner import TaskRunner
from repro.models import common as cm

PAPER_MODELS = ["llama3.1-8b", "qwen3-32b", "qwen3-235b", "deepseek-v3"]


def test_paper_models_registered_but_not_in_matrix():
    matrix = set(list_archs())
    everything = set(list_archs(include_perf_only=True))
    assert set(PAPER_MODELS) <= everything - matrix


@pytest.mark.parametrize("arch", PAPER_MODELS)
def test_reduced_forward(arch):
    cfg = get_config(arch).reduced()
    if arch == "deepseek-v3":
        cfg = dataclasses.replace(cfg, n_shared_experts=1)
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                              cfg.vocab_size)
    h, aux = models.forward_train(params, cfg, toks)
    assert h.shape == (2, 12, cfg.d_model)
    assert jnp.isfinite(h).all()


def test_deepseek_shared_expert_decode_consistency():
    cfg = dataclasses.replace(get_config("deepseek-v3").reduced(),
                              n_shared_experts=1)
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 13), 0,
                              cfg.vocab_size)
    h, _ = models.forward_train(params, cfg, toks)
    ref = cm.lm_logits(params["embed"], h[:, -1:], cfg)
    _, cache = models.prefill(params, cfg, toks[:, :12], max_len=20)
    lg, _ = models.decode_step(params, cfg, toks[:, 12:13], cache)
    assert float(jnp.max(jnp.abs(lg - ref))) < 1e-3


# ---------------------------------------------------------------------------
# parallelism enumeration: pp is clamped to the model's depth
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def sol_db():
    # parallelism enumeration never queries latencies: the speed-of-light
    # database skips grid collection and keeps this sweep instant
    return PerfDatabase("tpu_v5e", "repro-jax", use_grid=False)


def _workload(arch, chips=256):
    return WorkloadDescriptor(model=arch, isl=128, osl=32, sla=SLA(),
                              cluster=ClusterSpec(n_chips=chips),
                              modes=("aggregated",))


@pytest.mark.parametrize("arch", sorted(list_archs(include_perf_only=True)))
def test_pp_never_exceeds_num_layers_across_config_zoo(arch, sol_db):
    runner = TaskRunner(_workload(arch), db=sol_db)
    cands = runner.parallelism_candidates()
    assert cands
    for par in cands:
        assert par.pp <= min(8, runner.cfg.num_layers), \
            f"{arch}: pp={par.pp} exceeds num_layers={runner.cfg.num_layers}"
        assert par.tp * par.pp <= 256


def test_pp_clamped_on_shallow_model(sol_db):
    # a 3-layer variant: pp=4 would leave a pipeline stage with no layers,
    # so enumeration must stop at pp=2 even though chips allow far more
    w = _workload("llama3.1-8b", chips=64)
    shallow = dataclasses.replace(get_config("llama3.1-8b"), num_layers=3)
    runner = TaskRunner(w, session=InferenceSession(w, sol_db, cfg=shallow))
    pps = {par.pp for par in runner.parallelism_candidates()}
    assert pps == {1, 2}


def test_shared_experts_change_output():
    base = get_config("deepseek-v3").reduced()
    with_se = dataclasses.replace(base, n_shared_experts=1)
    p = models.init_params(with_se, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0,
                              base.vocab_size)
    h1, _ = models.forward_train(p, with_se, toks)
    # zeroing the shared-expert weights must change the result
    p2 = jax.tree.map(lambda x: x, p)
    p2["layers"]["ws_gate"] = jnp.zeros_like(p2["layers"]["ws_gate"])
    h2, _ = models.forward_train(p2, with_se, toks)
    assert float(jnp.max(jnp.abs(h1 - h2))) > 1e-4
