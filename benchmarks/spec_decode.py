"""Beyond-paper: speculative-decoding design sweep (the paper's §7 future
work).  Projects the draft-γ trade-off for qwen3-32b with a llama3.1-8b
draft on 8 chips across acceptance rates."""
from __future__ import annotations

from benchmarks.common import bench_main, finalize_result, write_csv
from repro.core import ClusterSpec, PerfDatabase, SLA, WorkloadDescriptor
from repro.core.config import ParallelismConfig
from repro.core.speculative import SpeculativeEstimator


def run(quick: bool = False):
    w = WorkloadDescriptor(
        model="qwen3-32b", isl=2048, osl=256,
        sla=SLA(ttft_ms=5000), cluster=ClusterSpec(n_chips=8),
        backend="repro-jax", dtype="fp8")
    est = SpeculativeEstimator(w, draft_model="llama3.1-8b",
                               db=PerfDatabase("tpu_v5e", "repro-jax"))
    par = ParallelismConfig(tp=8)
    rows = []
    best_overall = None
    for acc in ((0.8,) if quick else (0.5, 0.7, 0.8, 0.9)):
        best, projs = est.best_gamma(par, batch=8, acceptance=acc)
        for p in projs:
            rows.append([acc, p.gamma, f"{p.tpot_ms:.3f}",
                         f"{p.speedup_vs_autoregressive:.2f}",
                         f"{p.accepted_per_round:.2f}"])
        print(f"  acceptance {acc:.2f}: best gamma={best.gamma} "
              f"speedup {best.speedup_vs_autoregressive:.2f}x "
              f"({best.tokens_per_s_user:.0f} tok/s/user)")
        if best_overall is None or (best.speedup_vs_autoregressive
                                    > best_overall.speedup_vs_autoregressive):
            best_overall = best
    path = write_csv("spec_decode.csv",
                     ["acceptance", "gamma", "tpot_ms", "speedup",
                      "accepted_per_round"], rows)
    return finalize_result(
        {"csv": path,
         "best_speedup": best_overall.speedup_vs_autoregressive})


if __name__ == "__main__":
    bench_main(run)
