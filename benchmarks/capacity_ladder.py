"""Capacity ladder — goodput and cost-per-attained-token vs replicas.

Replays one seeded bursty trace across a ladder of replica counts for
each routing policy, recording aggregate goodput, SLO attainment, p99
TTFT, load imbalance, and the planner's cost metric: chip-seconds per
thousand attained tokens (``total_chips / goodput * 1000``).  Goodput
saturates once the deployment absorbs the bursts — beyond that point
extra replicas only raise the cost column, which is exactly the
trade-off ``plan_min_chips`` automates.

    PYTHONPATH=src python -m benchmarks.capacity_ladder [--quick]
"""
from __future__ import annotations

from benchmarks.common import bench_main, finalize_result, write_csv
from repro.api import Configurator
from repro.capacity import DeploymentSpec, ROUTING_POLICIES
from repro.core.task_runner import TaskRunner
from repro.workloads import (ArrivalSpec, LengthSpec, SLOSpec, TenantSpec,
                             TraceSpec, candidate_from_projection,
                             generate_trace)

LADDER = (1, 2, 4, 8)
SEED = 7


def _trace(n: int):
    return generate_trace(TraceSpec(
        n_requests=n,
        arrivals=ArrivalSpec(kind="bursty", rate_rps=60.0, burst_factor=4.0),
        tenants=(
            TenantSpec(name="chat", weight=0.7, priority=1,
                       lengths=LengthSpec(kind="lognormal", isl=256,
                                          osl=64)),
            TenantSpec(name="batch", weight=0.3,
                       lengths=LengthSpec(kind="lognormal", isl=512,
                                          osl=96)),
        )), seed=SEED)


def run(quick: bool = False):
    ladder = LADDER[:3] if quick else LADDER
    routings = ("round_robin",) if quick else ROUTING_POLICIES
    trace = _trace(40 if quick else 80)
    slo = SLOSpec(ttft_p99_ms=400, tpot_p99_ms=50)

    cfg = (Configurator.for_model("llama3.1-8b")
           .traffic(isl=256, osl=64)
           .sla(ttft_ms=2000, min_tokens_per_s_user=10)
           .cluster(chips=8, platform="tpu_v5e")
           .dtype("fp8")
           .modes("aggregated"))
    report = cfg.search(generate_launch=False)
    candidate = candidate_from_projection(report.top_k(1)[0])
    runner = TaskRunner(report.workload)

    rows = []
    min_chips = None
    for routing in routings:
        for replicas in ladder:
            dep = DeploymentSpec(candidate=candidate, replicas=replicas)
            m = runner.cluster_simulator(dep, routing=routing).replay(
                trace, slo=slo)
            attains = m.slo_attainment >= 0.95
            cost = (dep.total_chips / m.goodput_tok_s * 1000
                    if m.goodput_tok_s else float("inf"))
            if routing == routings[0] and attains and min_chips is None:
                min_chips = dep.total_chips
            rows.append([routing, replicas, dep.total_chips,
                         f"{m.goodput_tok_s:.1f}",
                         f"{100 * m.slo_attainment:.1f}",
                         f"{m.ttft_ms['p99']:.1f}",
                         f"{m.imbalance['routed_cv']:.3f}",
                         f"{cost:.3f}", int(attains)])
            print(f"  {routing:18s} x{replicas}: goodput "
                  f"{m.goodput_tok_s:8.1f} tok/s  attainment "
                  f"{100 * m.slo_attainment:5.1f}%  "
                  f"chip-s/ktok {cost:7.3f}  "
                  f"{'ATTAINS' if attains else 'misses'}")

    path = write_csv(
        "capacity_ladder.csv",
        ["routing", "replicas", "total_chips", "goodput_tok_s",
         "slo_attainment_pct", "p99_ttft_ms", "routed_cv",
         "chip_s_per_ktok", "attains"], rows)
    print(f"  min-chip deployment ({routings[0]}): "
          f"{min_chips if min_chips is not None else 'none on ladder'}")
    return finalize_result(
        {"csv": path, "min_chips": min_chips, "n_points": len(rows)})


if __name__ == "__main__":
    bench_main(run)
