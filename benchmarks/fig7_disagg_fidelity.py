"""Fig. 7 — disaggregated-serving prediction fidelity (DeepSeek-V3).

The configurator's Algorithm 3 projections (rate-matched (x)P(y)D with
α/β correction constants) are validated against a step-accurate two-pool
discrete-event simulation: prefill workers batch-prefill from a queue,
finished prefills transfer KV (P2P cost from the operator DB) and wait for
decode slots; decode workers step token by token.  Queueing, transfer and
tail effects that Algorithm 3 folds into constants emerge naturally — the
MAPE between the two reproduces the paper's Fig. 7 methodology.

Adaptation: DeepSeek-V3 fp8 weights (~671 GB) need >=64 v5e chips (16 GiB
HBM each); the paper's 2x8 H100 node pair is replaced by a 128-chip slice.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import List

from benchmarks.common import bench_main, finalize_result, mape, pearson, write_csv
from repro.core import (ClusterSpec, PerfDatabase, SLA, TaskRunner,
                        WorkloadDescriptor)
from repro.core import operators as ops
from repro.core.config import RuntimeFlags
from repro.core.modes import DisaggBest
from repro.core.session import InferenceSession
from repro.serving.sim import StepSpec


def simulate_disagg(session: InferenceSession, d: DisaggBest, isl: int,
                    osl: int, n_requests: int = 48) -> dict:
    """Event-driven two-pool ground truth."""
    flags = RuntimeFlags()
    pre_par = d.prefill.config.parallel
    dec_par = d.decode.config.parallel
    b_pre = d.prefill.config.batch_size
    b_dec = d.decode.config.batch_size

    t_prefill = session.spec_latency_ms(
        pre_par, StepSpec(prefill=tuple((isl, 0) for _ in range(b_pre)),
                          decode=()), flags) / 1e3
    # KV transfer: full cache for one request over the interconnect
    cfg = session.cfg
    kv_bytes = (cfg.num_layers * 2 * isl * 576 * 1
                if cfg.attention_kind == "mla" else
                cfg.num_layers * 2 * isl * cfg.num_kv_heads * cfg.head_dim)
    t_xfer = session.db.op_latency(
        ops.Comm("p2p", float(kv_bytes), 2, inter_pod=True))

    def t_decode_step(n_active: int, kv_len: int) -> float:
        return session.spec_latency_ms(
            dec_par, StepSpec(prefill=(),
                              decode=(kv_len,) * max(n_active, 1)),
            flags) / 1e3

    # events: prefill workers cycle batches; decode pool steps continuously
    queue_ready: List[float] = []     # times KV arrives at decode pool
    t = 0.0
    done_batches = 0
    per_worker_next = [0.0] * d.x
    produced = 0
    while produced < n_requests:
        w = min(range(d.x), key=lambda i: per_worker_next[i])
        start = per_worker_next[w]
        finish = start + t_prefill
        per_worker_next[w] = finish
        for _ in range(min(b_pre, n_requests - produced)):
            queue_ready.append(finish + t_xfer)
            produced += 1
    queue_ready.sort()

    # decode pool: y workers, each with b_dec slots, synchronized steps
    slots = d.y * b_dec
    ttfts, finish_times = [], []
    active: List[int] = []            # remaining tokens per active request
    waiting = list(queue_ready)
    t = waiting[0] if waiting else 0.0
    gen_total = 0
    tpot_samples = []
    while waiting or active:
        while waiting and waiting[0] <= t and len(active) < slots:
            ttfts.append(waiting.pop(0))
            active.append(osl - 1)
        if not active:
            t = waiting[0]
            continue
        # step-accurate KV growth: mean generated so far across active rows
        mean_gen = osl - sum(active) / len(active)
        dt = t_decode_step(len(active), isl + int(mean_gen))
        t += dt
        gen_total += len(active)
        if len(active) >= min(slots, n_requests) // 2:
            tpot_samples.append(dt)     # steady-state region
        active = [r - 1 for r in active if r > 1]
    total_tokens = n_requests * osl
    wall = t - (queue_ready[0] - t_prefill - t_xfer if queue_ready else 0.0)
    sys_thru = total_tokens / max(wall, 1e-9)
    mean_tpot = (sum(tpot_samples) / len(tpot_samples)) if tpot_samples \
        else t_decode_step(min(slots, n_requests), isl + osl // 2)
    speed = 1.0 / max(mean_tpot, 1e-9)
    return {"throughput_tok_s": sys_thru,
            "tok_s_per_chip": sys_thru / d.total_chips,
            "speed_tok_s_user": speed,
            "ttft_s": (ttfts[0] - 0.0) if ttfts else 0.0}


def run(quick: bool = False):
    db = PerfDatabase("tpu_v5e", "trtllm")
    rows = []
    preds_t, trues_t, preds_s, trues_s = [], [], [], []
    for isl in ((5000,) if quick else (5000, 6000)):
        w = WorkloadDescriptor(
            model="deepseek-v3", isl=isl, osl=1000,
            sla=SLA(ttft_ms=5000.0),
            cluster=ClusterSpec(n_chips=128), backend="trtllm", dtype="fp8",
            modes=("disaggregated",))
        res = TaskRunner(w, db).run(keep_all_disagg=True)
        session = InferenceSession(w, db)
        # validate the Pareto-optimal configs (paper: each frontier point)
        cands = sorted({(d.x, d.y, id(d)): d for d in
                        ([res.disagg_best] if res.disagg_best else [])
                        }.values(), key=lambda d: -d.tokens_per_s_per_chip)
        extra = [p for p in res.projections if p.mode == "disaggregated"]
        seen = set()
        frontier = []
        for d in ([res.disagg_best] if res.disagg_best else []):
            frontier.append(d)
        # sample more configs from the kept composite list via projections
        for d in frontier + _sample_composites(res, 6 if quick else 12):
            key = (d.x, d.y, d.prefill.config.describe(),
                   d.decode.config.describe())
            if key in seen:
                continue
            seen.add(key)
            gt = simulate_disagg(session, d, isl, 1000,
                                 n_requests=16 if quick else 48)
            pred_thru = d.tokens_per_s_per_chip
            pred_speed = 1000.0 / d.tpot_ms
            preds_t.append(pred_thru)
            trues_t.append(gt["tok_s_per_chip"])
            preds_s.append(pred_speed)
            trues_s.append(gt["speed_tok_s_user"])
            rows.append([isl, f"{d.x}P{d.y}D",
                         d.prefill.config.describe(),
                         d.decode.config.describe(),
                         f"{pred_thru:.1f}", f"{gt['tok_s_per_chip']:.1f}",
                         f"{pred_speed:.1f}",
                         f"{gt['speed_tok_s_user']:.1f}"])
    m_t, m_s = mape(preds_t, trues_t), mape(preds_s, trues_s)
    print(f"  disagg fidelity: throughput MAPE {m_t:.1f}% "
          f"(paper 25.5%), speed MAPE {m_s:.1f}% (paper 14.9%), "
          f"n={len(rows)}")
    path = write_csv("fig7_disagg_fidelity.csv",
                     ["isl", "xPyD", "prefill_cfg", "decode_cfg",
                      "thru_pred", "thru_true", "speed_pred", "speed_true"],
                     rows)
    return finalize_result(
        {"csv": path, "thru_mape": m_t, "speed_mape": m_s})


def _sample_composites(res, k):
    """Rebuild a few DisaggBest records from kept projections."""
    from repro.core import modes as md
    out = []
    for p in res.projections:
        if p.mode != "disaggregated" or len(out) >= k:
            continue
        pre, dec = p.config.get("prefill"), p.config.get("decode")
        if not pre or not dec:
            continue
        from repro.core.config import CandidateConfig, ParallelismConfig
        pre_c = CandidateConfig(
            parallel=ParallelismConfig(**{k2: pre["parallel"][k2]
                                          for k2 in ("tp", "pp", "ep", "dp")}),
            batch_size=pre["batch"])
        dec_c = CandidateConfig(
            parallel=ParallelismConfig(**{k2: dec["parallel"][k2]
                                          for k2 in ("tp", "pp", "ep", "dp")}),
            batch_size=dec["batch"])
        out.append(md.DisaggBest(
            prefill=md.PoolCandidate(pre_c, pre_c.parallel.chips_per_instance,
                                     0.0, 0.0),
            decode=md.PoolCandidate(dec_c, dec_c.parallel.chips_per_instance,
                                    p.tpot_ms, 0.0),
            x=pre["x"], y=dec["y"], ttft_ms=p.ttft_ms, tpot_ms=p.tpot_ms,
            total_chips=p.chips, req_per_s=0.0,
            tokens_per_s_per_chip=p.tokens_per_s_per_chip))
    return out


if __name__ == "__main__":
    bench_main(run)
