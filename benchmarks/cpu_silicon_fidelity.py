"""Real-silicon fidelity check — the strongest ground truth available in
this container.

The paper validates its estimator against GPU measurements.  Here the
"silicon" is this host CPU: we (1) micro-benchmark jit'd matmuls and
memory streams to calibrate a ``cpu_host`` Platform (measured peak
FLOP/s + bandwidth — the same calibration step the paper runs per GPU
SKU), (2) measure the engine's per-iteration host overhead, (3) run
Algorithm 2 over the PerfDatabase built on that platform, and (4) compare
against WALL-CLOCK TTFT/TPOT of the real continuous-batching engine
serving a reduced model.  Everything the paper does, end to end, with no
simulator in the ground-truth path.

Steps (1) and (2) are the ``repro.calibrate.host`` helpers — this
benchmark drives the calibration subsystem rather than carrying its own
measurement code.
"""
from __future__ import annotations

import dataclasses
import statistics
import time

import jax
import numpy as np

from benchmarks.common import bench_main, finalize_result, mape, write_csv
from repro import models
from repro.calibrate.host import (calibrate_cpu_platform,
                                  measure_engine_overheads)
from repro.configs import get_config
from repro.core import ClusterSpec, SLA, WorkloadDescriptor
from repro.core.backends.base import register
from repro.core.config import CandidateConfig, ParallelismConfig, RuntimeFlags
from repro.core.perf_database import PerfDatabase
from repro.core.session import InferenceSession
from repro.serving.engine import Engine, EngineConfig
from repro.serving.request import Request


def calibrate_backend(cfg, params, db) -> str:
    """Measure + register the engine-calibrated backend profile (the
    measurement itself lives in repro.calibrate.host)."""
    prof = measure_engine_overheads(cfg, params, db)
    register(prof)
    print(f"  calibrated repro-jax-cpu backend: step_overhead="
          f"{prof.step_overhead*1e3:.2f}ms chunk_overhead="
          f"{prof.chunk_overhead*1e3:.2f}ms")
    return prof.name


def run(quick: bool = False):
    platform = calibrate_cpu_platform()
    print(f"  calibrated cpu_host: {platform.peak_flops_bf16/1e9:.1f} GFLOP/s, "
          f"{platform.hbm_bw/1e9:.1f} GB/s")

    cfg = get_config("internlm2-1.8b").reduced()
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = models.init_params(cfg, jax.random.PRNGKey(0))

    rows, preds_tpot, trues_tpot, preds_ttft, trues_ttft = [], [], [], [], []
    db = PerfDatabase(platform, "repro-jax")
    backend_name = calibrate_backend(cfg, params, db)
    db.backend = backend_name
    for (isl, osl, conc) in ((16, 8, 2), (32, 16, 4)) if quick else \
            ((16, 8, 2), (32, 16, 4), (64, 16, 4), (32, 32, 8)):
        w = WorkloadDescriptor(
            model="internlm2-1.8b", isl=isl, osl=osl,
            sla=SLA(ttft_ms=1e9), cluster=ClusterSpec(n_chips=1,
                                                      platform="tpu_v5e"),
            backend=backend_name, dtype="fp32")   # reduced model is fp32
        session = InferenceSession(w, db, cfg=cfg)
        par = ParallelismConfig(tp=1)
        flags = RuntimeFlags()
        proj = session.evaluate_aggregated(
            CandidateConfig(parallel=par, batch_size=conc, flags=flags))
        if proj is None:
            continue

        eng = Engine(cfg, params, EngineConfig(max_batch=conc,
                                               max_seq=isl + osl + 8))
        rng = np.random.default_rng(0)
        n_req = 2 * conc + 2
        for i in range(n_req):
            prompt = rng.integers(0, cfg.vocab_size, isl).tolist()
            eng.add_request(Request(rid=i, isl=isl, osl=osl,
                                    arrival=time.perf_counter(),
                                    prompt=prompt))
        # warm the jits with one pass, then measure from fresh requests
        done = eng.run_until_drained()
        for i in range(n_req):
            prompt = rng.integers(0, cfg.vocab_size, isl).tolist()
            eng.add_request(Request(rid=100 + i, isl=isl, osl=osl,
                                    arrival=time.perf_counter(),
                                    prompt=prompt))
        done = eng.run_until_drained()
        ttft = statistics.median([r.ttft for r in done if r.ttft])
        tpot = statistics.median([r.tpot for r in done if r.tpot])
        rows.append([isl, osl, conc, f"{proj.tpot_ms:.2f}",
                     f"{1e3*tpot:.2f}", f"{proj.ttft_ms:.2f}",
                     f"{1e3*ttft:.2f}"])
        preds_tpot.append(proj.tpot_ms)
        trues_tpot.append(1e3 * tpot)
        preds_ttft.append(proj.ttft_ms)
        trues_ttft.append(1e3 * ttft)
        print(f"  isl={isl} osl={osl} conc={conc}: "
              f"TPOT pred {proj.tpot_ms:.1f} vs real {1e3*tpot:.1f} ms | "
              f"TTFT pred {proj.ttft_ms:.1f} vs real {1e3*ttft:.1f} ms")
    m_tpot = mape(preds_tpot, trues_tpot)
    m_ttft = mape(preds_ttft, trues_ttft)
    print(f"  REAL-silicon MAPE: TPOT {m_tpot:.1f}%  TTFT {m_ttft:.1f}% "
          f"(paper on GPUs: 8-12% / 17-22%)")
    print("  reading: this run validates the paper's THESIS by stress test "
          "— with platform+backend\n  calibration from 30s of "
          "micro-benchmarks the operator model lands within ~2x of real\n"
          "  wall-clock on completely foreign silicon; closing the rest "
          "needs exactly what the\n  paper does: ~30 GPU-hours of "
          "exhaustive per-(platform, framework) profiling, which\n  the "
          "PerfDatabase.save/load machinery here is built to ingest.")
    path = write_csv("cpu_silicon_fidelity.csv",
                     ["isl", "osl", "conc", "tpot_pred_ms", "tpot_real_ms",
                      "ttft_pred_ms", "ttft_real_ms"], rows)
    return finalize_result(
        {"csv": path, "tpot_mape": m_tpot, "ttft_mape": m_ttft})


if __name__ == "__main__":
    bench_main(run)
