"""Fig. 6 — aggregated-serving prediction fidelity.

Sweeps the paper's §5.1 grid (ISL 128–4096, OSL 128–512, concurrency
4–128, TP 1–8) for Qwen3-32B (dense, fp8) and Qwen3-235B (MoE, fp8) on the
repro-jax backend plus Qwen3-32B on the vllm backend, predicting TPOT/TTFT
with Algorithm 2 and validating against the step-accurate discrete-event
simulator (the silicon stand-in).  Reports MAPE + Pearson r per
(model, metric), mirroring the paper's panels.
"""
from __future__ import annotations

from benchmarks.common import (bench_main, finalize_result, mape,
                               pearson, sim_latency_fn, write_csv)
from repro.core import ClusterSpec, PerfDatabase, SLA, WorkloadDescriptor
from repro.core.config import CandidateConfig, ParallelismConfig, RuntimeFlags
from repro.core.session import InferenceSession
from repro.serving.scheduler import SchedulerConfig
from repro.serving.sim import ServingSimulator

PANELS = [
    ("qwen3-32b", "repro-jax", "fp8"),
    ("qwen3-235b", "repro-jax", "fp8"),
    ("qwen3-32b", "vllm", "fp8"),
]

ISLS = (128, 512, 2048, 4096)
OSLS = (128, 512)
CONCURRENCY = (4, 16, 64, 128)
TPS = (4, 8, 16)


def run(quick: bool = False):
    isls = ISLS[:2] if quick else ISLS
    oslr = OSLS[:1] if quick else OSLS
    concs = CONCURRENCY[:2] if quick else CONCURRENCY
    tps = TPS[:2] if quick else TPS

    rows, summary = [], []
    for model, backend, dtype in (PANELS[:1] if quick else PANELS):
        db = PerfDatabase("tpu_v5e", backend)
        preds_tpot, trues_tpot, preds_ttft, trues_ttft = [], [], [], []
        n_cfg = 0
        for tp in tps:
            w = WorkloadDescriptor(
                model=model, isl=max(isls), osl=max(oslr),
                sla=SLA(ttft_ms=1e9), cluster=ClusterSpec(n_chips=tp),
                backend=backend, dtype=dtype)
            session = InferenceSession(w, db)
            par = ParallelismConfig(tp=tp)
            flags = RuntimeFlags()
            for isl in isls:
                for osl in oslr:
                    for conc in concs:
                        w2 = WorkloadDescriptor(
                            model=model, isl=isl, osl=osl,
                            sla=SLA(ttft_ms=1e9),
                            cluster=ClusterSpec(n_chips=tp),
                            backend=backend, dtype=dtype)
                        s2 = InferenceSession(w2, db)
                        cand = CandidateConfig(parallel=par, batch_size=conc,
                                               flags=flags)
                        proj = s2.evaluate_aggregated(cand)
                        if proj is None:
                            continue            # doesn't fit HBM
                        sim = ServingSimulator(
                            SchedulerConfig(max_batch=conc,
                                            max_num_tokens=flags.max_num_tokens),
                            sim_latency_fn(s2, par, flags))
                        m = sim.run(isl=isl, osl=osl, concurrency=conc,
                                    max_requests=max(2 * conc, 12),
                                    warmup=max(conc // 2, 2))
                        if m.tpot_ms <= 0:
                            continue
                        n_cfg += 1
                        preds_tpot.append(proj.tpot_ms)
                        trues_tpot.append(m.tpot_ms)
                        # paper filters TTFT > 1000ms as pathological queuing
                        if m.ttft_ms <= 1000.0:
                            preds_ttft.append(proj.ttft_ms)
                            trues_ttft.append(m.ttft_ms)
                        rows.append([model, backend, tp, isl, osl, conc,
                                     f"{proj.tpot_ms:.3f}", f"{m.tpot_ms:.3f}",
                                     f"{proj.ttft_ms:.1f}", f"{m.ttft_ms:.1f}"])
        mt = mape(preds_tpot, trues_tpot)
        rt = pearson(preds_tpot, trues_tpot)
        mf = mape(preds_ttft, trues_ttft)
        rf = pearson(preds_ttft, trues_ttft)
        summary.append([model, backend, n_cfg, f"{mt:.1f}", f"{rt:.3f}",
                        f"{mf:.1f}", f"{rf:.3f}"])
        print(f"  {model}/{backend}: {n_cfg} cfgs  "
              f"TPOT MAPE {mt:.1f}% (r={rt:.2f})  "
              f"TTFT MAPE {mf:.1f}% (r={rf:.2f})")

    write_csv("fig6_fidelity_points.csv",
              ["model", "backend", "tp", "isl", "osl", "concurrency",
               "tpot_pred_ms", "tpot_true_ms", "ttft_pred_ms", "ttft_true_ms"],
              rows)
    path = write_csv("fig6_fidelity_summary.csv",
                     ["model", "backend", "n_configs", "tpot_mape_pct",
                      "tpot_r", "ttft_mape_pct", "ttft_r"], summary)
    return finalize_result({"csv": path, "summary": summary})


if __name__ == "__main__":
    bench_main(run)
