"""Table 2 — production case study: optimal aggregated vs disaggregated
configuration for Qwen3-32B-FP8 under SLA (TTFT<=1200ms, >=60 tok/s/user).

The paper uses 8 H200s; on 16GiB-HBM v5e chips the same model needs 16
chips for comparable headroom (documented adaptation).  Runs through the
``repro.api`` facade — the same code path as the CLI and the examples —
and emits the launch artifacts for both winners plus the full
schema-versioned SearchReport.
"""
from __future__ import annotations

import os

from benchmarks.common import (RESULTS_DIR, bench_main, finalize_result,
                               write_csv)
from repro.api import Configurator
from repro.core.generator import generate


def run(quick: bool = False):
    report = (Configurator.for_model("qwen3-32b")
              .traffic(isl=4000, osl=500)
              .sla(ttft_ms=1200.0, min_tokens_per_s_user=60)
              .cluster(chips=16, platform="tpu_v5e")
              .backend("repro-jax").dtype("fp8")
              .search())
    w = report.workload

    rows, launches = [], {}
    for mode in ("aggregated", "disaggregated"):
        cands = [p for p in report.projections
                 if p.mode == mode and p.meets(w.sla)]
        if not cands:
            rows.append([mode, "-", "-", "-", "-", "no SLA-valid config"])
            continue
        best = max(cands, key=lambda p: p.tokens_per_s_per_chip)
        lc = report.launch if best is report.best else generate(w, best)
        launches[mode] = lc
        rows.append([mode, f"{best.tokens_per_s_per_chip:.1f}",
                     f"{best.tokens_per_s_user:.1f}",
                     f"{best.ttft_ms:.1f}", best.batch_size,
                     best.config.get("describe", "")])
        print(f"  {mode:14s} {best.tokens_per_s_per_chip:7.1f} tok/s/chip  "
              f"{best.tokens_per_s_user:5.1f} tok/s/user  "
              f"TTFT {best.ttft_ms:6.1f}ms  {best.config.get('describe','')}")

    path = write_csv("table2_case_study.csv",
                     ["mode", "tokens_per_s_per_chip", "tokens_per_s_user",
                      "ttft_ms", "batch", "config"], rows)
    os.makedirs(RESULTS_DIR, exist_ok=True)
    report.save(os.path.join(RESULTS_DIR, "table2_report.json"))
    for mode, lc in launches.items():
        with open(os.path.join(RESULTS_DIR, f"launch_{mode}.json"), "w") as f:
            f.write(lc.to_json())
        print(f"  launch[{mode}]: {lc.command}")
    out = {"csv": path}
    if len(launches) == 2:
        agg = float(rows[0][1])
        dis = float(rows[1][1])
        out["gain_pct"] = 100.0 * (dis - agg) / agg
        print(f"  disaggregation gain: {out['gain_pct']:+.1f}% "
              f"(paper: +101.6%)")
    return finalize_result(out)


if __name__ == "__main__":
    bench_main(run)
