"""§Roofline — three-term roofline per (arch x shape x mesh) from the
dry-run artifacts (results/dryrun.jsonl).

  compute term    = HLO_FLOPs / peak_FLOP/s            (per chip, s)
  memory term     = HLO_bytes / HBM_bw                 (per chip, s)
  collective term = collective_bytes / link_bw         (per chip, s)

HLO_FLOPs/bytes are the trip-count-corrected per-device numbers from
launch/hlo_analysis (raw cost_analysis counts loop bodies once — recorded
alongside for reference).  MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D
(MoE) scaled x3 for train (fwd+bwd) vs x2... (6ND already includes bwd;
serve steps use 2·N·D).  Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s
HBM, 2x50 GB/s ICI per torus axis.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

from benchmarks.common import bench_main, finalize_result, write_csv
from repro.configs import INPUT_SHAPES, get_config

PEAK = 197e12
HBM = 819e9
ICI = 2 * 50e9          # bidirectional ring per axis
DCI = 25e9              # pod axis

DRYRUN = os.environ.get("REPRO_DRYRUN", "results/dryrun.jsonl")


def operator_bytes_per_chip(arch: str, shape_name: str, mesh: str) -> float:
    """Memory-term numerator from the operator-level model (the paper's own
    decomposition).  The HLO-text byte count is kept alongside as an upper
    bound: the CPU backend splits flash-attention softmax chains into ~6
    unfused 100MB round-trips per block that a TPU fuses into one kernel
    (measured 5-8x inflation on attention-heavy pairs)."""
    from repro.core import decompose
    from repro.core.config import ParallelismConfig
    from repro.serving.sim import StepSpec

    cfg = get_config(arch)
    sh = INPUT_SHAPES[shape_name]
    data_ways = 32 if mesh == "2x16x16" else 16
    b_loc = max(sh.global_batch // data_ways, 1)
    par = ParallelismConfig(tp=16)
    if sh.kind == "decode":
        spec = StepSpec(prefill=(), decode=(sh.seq_len,) * b_loc)
        mult = 1.0
    else:
        spec = StepSpec(prefill=tuple((sh.seq_len, 0) for _ in range(b_loc)),
                        decode=())
        # train: bwd ~2x fwd traffic + full-remat recompute ~1x fwd
        mult = 4.0 if sh.kind == "train" else 1.0
    ops_list = decompose.iteration_ops(cfg, par, spec)
    total = sum(op.bytes() * count for op, count in ops_list) * mult
    if sh.kind == "train":
        # AdamW: read+write fp32 m,v + param read/write + fp32 grads
        params_local = decompose.param_bytes_per_chip(cfg, par) / data_ways
        total += params_local * 14
    return total


def model_flops_per_chip(arch: str, shape_name: str, chips: int) -> float:
    cfg = get_config(arch)
    sh = INPUT_SHAPES[shape_name]
    n = cfg.active_param_count()
    if sh.kind == "train":
        tokens = sh.global_batch * sh.seq_len
        total = 6.0 * n * tokens            # fwd+bwd
    elif sh.kind == "prefill":
        tokens = sh.global_batch * sh.seq_len
        total = 2.0 * n * tokens
    else:
        tokens = sh.global_batch            # one token per row
        total = 2.0 * n * tokens
    return total / chips


def load(path: str = DRYRUN) -> List[Dict]:
    recs = {}
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            recs[(r["arch"], r["shape"], r["mesh"])] = r   # last wins
    return list(recs.values())


def analyze_record(r: Dict) -> Optional[Dict]:
    if not r.get("ok"):
        return None
    chips = 512 if r["mesh"] == "2x16x16" else 256
    t_c = r["flops_corrected"] / PEAK
    t_m_hlo = r["bytes_corrected"] / HBM
    t_m = operator_bytes_per_chip(r["arch"], r["shape"], r["mesh"]) / HBM
    coll = r.get("collectives", {})
    intra = sum(v for k, v in coll.items())
    t_x = intra / ICI
    dominant = max(("compute", t_c), ("memory", t_m),
                   ("collective", t_x), key=lambda kv: kv[1])[0]
    mf = model_flops_per_chip(r["arch"], r["shape"], chips)
    ratio = mf / r["flops_corrected"] if r["flops_corrected"] else 0.0
    mem = r.get("mem", {})
    temp = mem.get("temp_size_in_bytes", 0.0)
    args = mem.get("argument_size_in_bytes", 0.0)
    return {
        "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
        "t_compute_s": t_c, "t_memory_s": t_m, "t_memory_hlo_s": t_m_hlo,
        "t_collective_s": t_x,
        "dominant": dominant,
        "model_flops": mf, "hlo_flops": r["flops_corrected"],
        "useful_ratio": ratio,
        "mem_gib": (temp + args) / 2**30,
        "flops_raw": r["flops"],
    }


def run(quick: bool = False, path: str = DRYRUN):
    if not os.path.exists(path):
        print(f"  no dry-run artifact at {path}; run "
              "`python -m repro.launch.dryrun --all` first")
        return finalize_result({"csv": None})
    rows = []
    for r in load(path):
        a = analyze_record(r)
        if a is None:
            rows.append([r["arch"], r["shape"], r["mesh"], "FAILED",
                         "", "", "", "", "", ""])
            continue
        rows.append([a["arch"], a["shape"], a["mesh"],
                     f"{a['t_compute_s']*1e3:.3f}",
                     f"{a['t_memory_s']*1e3:.3f}",
                     f"{a['t_memory_hlo_s']*1e3:.3f}",
                     f"{a['t_collective_s']*1e3:.3f}",
                     a["dominant"], f"{a['useful_ratio']:.3f}",
                     f"{a['mem_gib']:.2f}",
                     f"{a['hlo_flops']:.3e}"])
    rows.sort(key=lambda r: (r[0], r[1], r[2]))
    out = write_csv("roofline.csv",
                    ["arch", "shape", "mesh", "t_compute_ms", "t_memory_ms",
                     "t_memory_hlo_ms", "t_collective_ms", "dominant",
                     "model/hlo_flops", "mem_gib", "hlo_flops_per_chip"],
                    rows)
    doms = {}
    for r in rows:
        doms[r[7]] = doms.get(r[7], 0) + 1
    print(f"  {len(rows)} (arch x shape x mesh) rooflines -> {out}")
    print(f"  dominant terms: {doms}")
    return finalize_result({"csv": out, "dominants": doms})


if __name__ == "__main__":
    bench_main(run)
