"""Fig. 1 — throughput-vs-speed Pareto frontiers, Qwen3-235B on 64 chips.

Plots (as CSV) every TTFT<=1000ms config for aggregated and disaggregated
serving at ISL 4096 / OSL 1024, and stars the best config above
20 tokens/s/user — reproducing the paper's headline "disaggregated wins
~50%" observation.  Runs through the ``repro.api`` facade.
"""
from __future__ import annotations

from benchmarks.common import bench_main, finalize_result, write_csv
from repro.api import Configurator


def run(quick: bool = False):
    report = (Configurator.for_model("qwen3-235b")
              .traffic(isl=4096, osl=1024)
              .sla(ttft_ms=1000.0, min_tokens_per_s_user=20)
              .cluster(chips=64, platform="tpu_v5e")
              .backend("trtllm").dtype("fp8")
              .search(keep_all_disagg=not quick))
    w = report.workload

    rows = []
    for p in report.projections:
        if p.ttft_ms > w.sla.ttft_ms:
            continue
        rows.append([p.mode, f"{p.tokens_per_s_user:.2f}",
                     f"{p.tokens_per_s_per_chip:.2f}", f"{p.ttft_ms:.1f}",
                     p.batch_size, p.config.get("describe", "")])
    path = write_csv("fig1_pareto_points.csv",
                     ["mode", "tokens_per_s_user", "tokens_per_s_per_chip",
                      "ttft_ms", "batch", "config"], rows)

    best = {}
    for mode in ("aggregated", "disaggregated"):
        cands = [p for p in report.projections
                 if p.mode == mode and p.meets(w.sla)]
        if cands:
            best[mode] = max(cands, key=lambda p: p.tokens_per_s_per_chip)
    out = {"csv": path}
    if "aggregated" in best and "disaggregated" in best:
        agg = best["aggregated"].tokens_per_s_per_chip
        dis = best["disaggregated"].tokens_per_s_per_chip
        gain = 100.0 * (dis - agg) / agg
        out.update(agg_best=agg, disagg_best=dis, gain_pct=gain)
        print(f"  agg*  : {agg:8.1f} tok/s/chip "
              f"({best['aggregated'].config.get('describe')})")
        print(f"  disagg*: {dis:8.1f} tok/s/chip "
              f"({best['disaggregated'].config.get('describe')})")
        print(f"  disaggregation gain under SLA: {gain:+.1f}% "
              f"(paper: ~+53%)")
    return finalize_result(out)


if __name__ == "__main__":
    bench_main(run)
