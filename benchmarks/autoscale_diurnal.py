"""Autoscale diurnal — chip-seconds saved vs curve shape and cooldown.

Replays one model's best engine under seeded diurnal traces of
increasing amplitude, comparing the reactive autoscaler
(``target_queue_depth``) against the static ``plan_min_chips``
baseline on chip-seconds and SLO attainment.  Two knobs are swept:

- **amplitude** — a flat curve (0.0) leaves an autoscaler nothing to
  harvest (the static plan is already right-sized); the deeper the
  trough, the more chip-seconds riding the curve down recovers;
- **down-cooldown** — too-eager scale-down claws back chip-seconds at
  the cost of attainment when the next crest arrives mid-cold-start;
  the asymmetric default (fast up, slow down) is that trade pre-made.

    PYTHONPATH=src python -m benchmarks.autoscale_diurnal [--quick]
"""
from __future__ import annotations

from benchmarks.common import bench_main, finalize_result, write_csv
from repro.autoscale import build_autoscale_section, get_policy
from repro.core.config import (CandidateConfig, ClusterSpec,
                               ParallelismConfig, RuntimeFlags, SLA,
                               WorkloadDescriptor)
from repro.core.task_runner import TaskRunner
from repro.workloads import (ArrivalSpec, LengthSpec, SLOSpec, TenantSpec,
                             TraceSpec, generate_trace)

AMPLITUDES = (0.0, 0.5, 0.9)
DOWN_COOLDOWNS = (8.0, 30.0)
SEED = 11


def _trace(amplitude: float, n: int):
    return generate_trace(TraceSpec(
        n_requests=n,
        arrivals=ArrivalSpec(kind="diurnal", rate_rps=1.2, period_s=60.0,
                             amplitude=amplitude),
        tenants=(TenantSpec(lengths=LengthSpec(kind="fixed", isl=512,
                                               osl=128)),)), seed=SEED)


def run(quick: bool = False):
    amplitudes = AMPLITUDES[-1:] if quick else AMPLITUDES
    cooldowns = DOWN_COOLDOWNS[:1] if quick else DOWN_COOLDOWNS
    n = 120 if quick else 250
    slo = SLOSpec(ttft_p99_ms=2500, tpot_p99_ms=100)

    # the one-chip engine the capacity/autoscale smoke stages exercise:
    # small enough that the diurnal crest genuinely needs two replicas
    w = WorkloadDescriptor(
        model="qwen3-32b", isl=512, osl=128, sla=SLA(),
        cluster=ClusterSpec(n_chips=4, platform="tpu_v5e"),
        modes=("aggregated",))
    candidate = CandidateConfig(parallel=ParallelismConfig(tp=1),
                                batch_size=16, flags=RuntimeFlags())
    runner = TaskRunner(w)

    rows = []
    best_pct = None
    for amplitude in amplitudes:
        trace = _trace(amplitude, n)
        for down_cd in cooldowns:
            policy = get_policy("target_queue_depth", target_depth=6.0,
                                max_replicas=2, up_cooldown_s=2.0,
                                down_cooldown_s=down_cd, window_s=5.0)
            section, asc = build_autoscale_section(
                runner, candidate, trace, slo, policy,
                ladder=(1, 2, 4), tick_s=1.0, cold_start_s=2.0)
            static = section["static"]
            savings = section["savings"]
            attain = asc.metrics.slo_attainment or 0.0
            pct = savings["chip_seconds_pct"] if savings else float("nan")
            holds = bool(savings and savings["holds_attainment"])
            if holds and (best_pct is None or pct > best_pct):
                best_pct = pct
            rows.append([f"{amplitude:.1f}", f"{down_cd:g}",
                         static["total_chips"] if static else "",
                         f"{static['chip_seconds']:.1f}" if static else "",
                         f"{asc.chip_seconds:.1f}", f"{pct:.1f}",
                         f"{asc.mean_replicas:.2f}", asc.peak_replicas,
                         asc.n_scale_ups, asc.n_scale_downs,
                         f"{100 * attain:.1f}", int(holds)])
            print(f"  amp {amplitude:.1f} down-cd {down_cd:4g}s: "
                  f"{asc.chip_seconds:7.1f} chip-s vs "
                  f"{static['chip_seconds'] if static else float('nan'):7.1f}"
                  f" static ({pct:5.1f}% saved)  attainment "
                  f"{100 * attain:5.1f}%  "
                  f"{'HOLDS' if holds else 'misses'}")

    path = write_csv(
        "autoscale_diurnal.csv",
        ["amplitude", "down_cooldown_s", "static_total_chips",
         "static_chip_s", "autoscaled_chip_s", "saved_pct",
         "mean_replicas", "peak_replicas", "scale_ups", "scale_downs",
         "slo_attainment_pct", "holds_attainment"], rows)
    print(f"  best saving that holds attainment: "
          f"{f'{best_pct:.1f}%' if best_pct is not None else 'none'}")
    return finalize_result(
        {"csv": path, "best_saved_pct": best_pct, "n_points": len(rows)})


if __name__ == "__main__":
    bench_main(run)
