"""Table 1 — configuration-search efficiency.

AIConfigurator search wall time + median per-config time for the paper's
three models, against two baselines: (a) our own step-accurate simulator
as the exhaustive-evaluation stand-in (measured on this machine), and
(b) the paper's reported GPU-benchmarking medians (4 / 5.4 / 11.5 min per
config on H100) for the speedup column.

``--batched`` runs the vectorized-pricing arm instead: for each model's
whole candidate space it times the scalar per-operator walk
(``PerfDatabase.sequence_latency`` over pre-built op lists, memos cold)
against the fused batch kernel (``sequence_latency_batch`` over the
pre-encoded ``OpBatch``), checks float parity and frontier identity of
the two search paths, and gates on >=50x kernel speedup (>=10x under
``--quick``).  The batched search's own phase breakdown (encode / kernel /
record / replay) comes from ``repro.obs`` tracing spans rather than ad-hoc
timers — the same spans ``search --trace-out`` captures.  The comparison
boundary is pricing, with op-list construction excluded from both arms.
"""
from __future__ import annotations

import statistics
import time

import numpy as np

from benchmarks.common import (Timer, finalize_result, sim_latency_fn,
                               write_csv)
from repro.core import (ClusterSpec, PerfDatabase, SLA, TaskRunner,
                        WorkloadDescriptor)
from repro.core.config import CandidateConfig, ParallelismConfig, RuntimeFlags
from repro.core.decompose import encode_iteration_batch, iteration_ops
from repro.core.session import InferenceSession
from repro.obs.trace import Tracer, disable_tracing, enable_tracing
from repro.serving.scheduler import SchedulerConfig
from repro.serving.sim import ServingSimulator

MODELS = [
    ("llama3.1-8b", "bf16", 4.0),      # paper GPU median min/config
    ("qwen3-32b", "fp8", 5.4),
    ("qwen3-235b", "fp8", 11.5),
]


def run(quick: bool = False):
    rows = []
    db = PerfDatabase("tpu_v5e", "repro-jax")
    for model, dtype, gpu_min in MODELS:
        w = WorkloadDescriptor(
            model=model, isl=1024, osl=256,
            sla=SLA(ttft_ms=2000, min_tokens_per_s_user=10),
            cluster=ClusterSpec(n_chips=64), backend="repro-jax", dtype=dtype)
        runner = TaskRunner(w, db)
        with Timer() as t:
            result = runner.run()
        # measured per-config cost of the step-accurate simulator baseline
        session = InferenceSession(w, db)
        par = ParallelismConfig(tp=8)
        flags = RuntimeFlags()
        sim = ServingSimulator(SchedulerConfig(max_batch=16,
                                               max_num_tokens=8192),
                               sim_latency_fn(session, par, flags))
        with Timer() as ts:
            sim.run(isl=w.isl, osl=64 if quick else w.osl, concurrency=16,
                    max_requests=8 if quick else 16)
        sim_s = ts.seconds

        per_cfg_ms = result.per_candidate_ms
        n = result.n_candidates
        gpu_hours = n * gpu_min / 60.0
        rows.append([model, n, f"{t.seconds:.2f}",
                     f"{per_cfg_ms:.2f}",
                     f"{sim_s:.2f}",
                     f"{sim_s * n / 3600:.1f}",
                     f"{gpu_hours:.1f}",
                     f"{gpu_hours * 3600 / max(t.seconds, 1e-9):,.0f}x"])
        print(f"  {model}: {n} configs in {t.seconds:.2f}s "
              f"({per_cfg_ms:.2f} ms/config); sim baseline {sim_s:.1f}s/config; "
              f"paper-GPU equiv {gpu_hours:.0f}h -> "
              f"{gpu_hours*3600/max(t.seconds,1e-9):,.0f}x speedup")
    path = write_csv(
        "table1_search_efficiency.csv",
        ["model", "n_configs", "search_total_s", "median_ms_per_config",
         "sim_baseline_s_per_config", "sim_total_h", "paper_gpu_total_h",
         "speedup_vs_gpu"],
        rows)
    return finalize_result(
        {"csv": path,
         "per_config_ms": statistics.median(
             float(r[3]) for r in rows)})


def _workload(model, dtype):
    return WorkloadDescriptor(
        model=model, isl=1024, osl=256,
        sla=SLA(ttft_ms=2000, min_tokens_per_s_user=10),
        cluster=ClusterSpec(n_chips=64), backend="repro-jax", dtype=dtype)


def _record_atoms(w, db):
    """Every (cfg, par, spec) pricing atom the search evaluates, in order."""
    runner = TaskRunner(w, db)
    session, cfg = runner.session, runner.session.cfg
    items = []
    for cand in runner.iter_candidates():
        mem = session._mem_ok(cand)
        if not mem[0]:
            continue
        for mode in w.modes:
            fn = (session.evaluate_static if mode == "static"
                  else session.evaluate_aggregated)
            _, rec = session.record_specs(
                lambda _f=fn, _c=cand, _m=mem:
                _f(_c, _mem=_m, _plan_only=True))
            items.extend((cfg, par, spec) for par, spec, _fl in rec)
    return items


def _frontier_key(result):
    return ([(p.mode, p.config.get("describe")) for p in result.frontier],
            result.best.config.get("describe") if result.best else None)


def run_batched(quick: bool = False):
    """Vectorized-pricing arm: parity + speedup of the fused batch kernel."""
    rows = []
    speedups = []
    models = MODELS[:1] if quick else MODELS
    for model, dtype, _gpu_min in models:
        w = _workload(model, dtype)
        db = PerfDatabase("tpu_v5e", "repro-jax")

        # the two search paths must agree exactly on what they find;
        # the batched arm runs traced, so its phase breakdown (encode /
        # kernel / record / replay) falls out of the spans
        scalar_res = TaskRunner(w, db).run(batched=False)
        tracer = enable_tracing(Tracer())
        try:
            with Timer() as tb:
                batched_res = TaskRunner(w, db).run(batched=True)
        finally:
            disable_tracing()
        wall = tracer.wall_by_name()
        if _frontier_key(scalar_res) != _frontier_key(batched_res):
            raise RuntimeError(f"{model}: batched search frontier diverged "
                               "from scalar")

        # pricing microbenchmark: same atoms, both arms, min over reps
        items = _record_atoms(w, db)
        batch = encode_iteration_batch(items, alpha=w.moe_alpha,
                                       backend=w.backend, dtype=w.dtype)
        out = db.sequence_latency_batch(batch)      # warms any lazy grids
        t_kernel = min(
            (lambda t0: (db.sequence_latency_batch(batch),
                         time.perf_counter() - t0)[1])(time.perf_counter())
            for _ in range(5 if quick else 20))

        op_lists = [iteration_ops(c, p, s, backend=w.backend, dtype=w.dtype,
                                  alpha=w.moe_alpha) for c, p, s in items]
        db2 = PerfDatabase(db.platform.name, w.backend, use_grid=True)
        for ol in op_lists:
            db2.sequence_latency(ol)                # warm every grid
        t_scalar = float("inf")
        for _ in range(3 if quick else 5):
            db2._memo.clear()
            db2._seq_memo.clear()
            t0 = time.perf_counter()
            ref = [db2.sequence_latency(ol) for ol in op_lists]
            t_scalar = min(t_scalar, time.perf_counter() - t0)
        ref = np.asarray(ref)
        maxrel = float(np.max(np.abs(out - ref) / np.maximum(ref, 1e-30)))
        if maxrel > 1e-9:
            raise RuntimeError(f"{model}: batch kernel diverged from scalar "
                               f"pricing (max rel {maxrel:.2e})")

        n = len(items)
        speedup = t_scalar / t_kernel
        speedups.append(speedup)
        phases = {k: wall.get(f, 0.0) for k, f in
                  (("encode", "price.encode"), ("kernel", "price.kernel"),
                   ("record", "search.record"), ("replay", "search.replay"))}
        rows.append([model, n, batch.n_rows,
                     f"{t_scalar / n * 1e6:.2f}",
                     f"{t_kernel / n * 1e6:.3f}",
                     f"{speedup:.1f}x",
                     f"{tb.seconds:.2f}",
                     f"{phases['encode']:.3f}",
                     f"{phases['kernel']:.3f}",
                     f"{phases['record']:.3f}",
                     f"{phases['replay']:.3f}",
                     f"{maxrel:.2e}"])
        print(f"  {model}: {n} atoms ({batch.n_rows} rows) "
              f"scalar {t_scalar / n * 1e6:.1f}us -> kernel "
              f"{t_kernel / n * 1e6:.2f}us per atom "
              f"({speedup:.1f}x, max rel {maxrel:.1e}); batched search "
              f"{tb.seconds:.2f}s [" +
              ", ".join(f"{k} {v:.2f}s" for k, v in phases.items()) + "]")
    path = write_csv(
        "table1_batched_pricing.csv",
        ["model", "n_atoms", "n_rows", "scalar_us_per_atom",
         "kernel_us_per_atom", "pricing_speedup", "batched_search_s",
         "search_encode_s", "search_kernel_s", "search_record_s",
         "search_replay_s", "max_rel_diff"],
        rows)
    gate = 10.0 if quick else 50.0
    if min(speedups) < gate:
        raise RuntimeError(
            f"batched pricing speedup {min(speedups):.1f}x below the "
            f"{gate:.0f}x gate")
    return finalize_result(
        {"csv": path, "pricing_speedup_min": min(speedups),
         "pricing_speedup_median": statistics.median(speedups)})


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--batched", action="store_true",
                    help="run the vectorized-pricing arm")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    if args.batched:
        run_batched(quick=args.quick)
    else:
        run(quick=args.quick)


if __name__ == "__main__":
    main()
