"""Table 1 — configuration-search efficiency.

AIConfigurator search wall time + median per-config time for the paper's
three models, against two baselines: (a) our own step-accurate simulator
as the exhaustive-evaluation stand-in (measured on this machine), and
(b) the paper's reported GPU-benchmarking medians (4 / 5.4 / 11.5 min per
config on H100) for the speedup column.
"""
from __future__ import annotations

import statistics
import time

from benchmarks.common import Timer, sim_latency_fn, write_csv
from repro.core import (ClusterSpec, PerfDatabase, SLA, TaskRunner,
                        WorkloadDescriptor)
from repro.core.config import CandidateConfig, ParallelismConfig, RuntimeFlags
from repro.core.session import InferenceSession
from repro.serving.scheduler import SchedulerConfig
from repro.serving.sim import ServingSimulator

MODELS = [
    ("llama3.1-8b", "bf16", 4.0),      # paper GPU median min/config
    ("qwen3-32b", "fp8", 5.4),
    ("qwen3-235b", "fp8", 11.5),
]


def run(quick: bool = False):
    rows = []
    db = PerfDatabase("tpu_v5e", "repro-jax")
    for model, dtype, gpu_min in MODELS:
        w = WorkloadDescriptor(
            model=model, isl=1024, osl=256,
            sla=SLA(ttft_ms=2000, min_tokens_per_s_user=10),
            cluster=ClusterSpec(n_chips=64), backend="repro-jax", dtype=dtype)
        runner = TaskRunner(w, db)
        with Timer() as t:
            result = runner.run()
        # measured per-config cost of the step-accurate simulator baseline
        session = InferenceSession(w, db)
        par = ParallelismConfig(tp=8)
        flags = RuntimeFlags()
        sim = ServingSimulator(SchedulerConfig(max_batch=16,
                                               max_num_tokens=8192),
                               sim_latency_fn(session, par, flags))
        with Timer() as ts:
            sim.run(isl=w.isl, osl=64 if quick else w.osl, concurrency=16,
                    max_requests=8 if quick else 16)
        sim_s = ts.seconds

        per_cfg_ms = result.per_candidate_ms
        n = result.n_candidates
        gpu_hours = n * gpu_min / 60.0
        rows.append([model, n, f"{t.seconds:.2f}",
                     f"{per_cfg_ms:.2f}",
                     f"{sim_s:.2f}",
                     f"{sim_s * n / 3600:.1f}",
                     f"{gpu_hours:.1f}",
                     f"{gpu_hours * 3600 / max(t.seconds, 1e-9):,.0f}x"])
        print(f"  {model}: {n} configs in {t.seconds:.2f}s "
              f"({per_cfg_ms:.2f} ms/config); sim baseline {sim_s:.1f}s/config; "
              f"paper-GPU equiv {gpu_hours:.0f}h -> "
              f"{gpu_hours*3600/max(t.seconds,1e-9):,.0f}x speedup")
    path = write_csv(
        "table1_search_efficiency.csv",
        ["model", "n_configs", "search_total_s", "median_ms_per_config",
         "sim_baseline_s_per_config", "sim_total_h", "paper_gpu_total_h",
         "speedup_vs_gpu"],
        rows)
    return {"csv": path,
            "per_config_ms": statistics.median(
                float(r[3]) for r in rows)}


if __name__ == "__main__":
    run()
