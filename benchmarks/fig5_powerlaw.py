"""Fig. 5 — the effect of the power-law skew parameter α on expert load
and on the modeled MoE layer latency (tail of the hottest EP rank)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import bench_main, finalize_result, write_csv
from repro.core import PerfDatabase, powerlaw
from repro.core import operators as ops

ALPHAS = (0.01, 0.4, 0.8, 1.2)


def run(quick: bool = False):
    T, K, E, EP = 8192, 8, 128, 16
    db = PerfDatabase("tpu_v5e", "trtllm")
    rows = []
    for alpha in ALPHAS:
        shares, hots, lats = [], [], []
        for seed in range(4 if quick else 16):
            counts = powerlaw.token_counts(T, K, E, alpha, seed)
            order = np.sort(counts)[::-1]
            shares.append(order[:E // 5].sum() / order.sum())
            hot = powerlaw.hot_rank_tokens(T, K, E, EP, alpha, seed)
            hots.append(hot)
            lats.append(db.op_latency(ops.MoEOp(
                tokens=T, d_model=4096, d_ff=1536, num_experts=E, top_k=K,
                ep=EP, hot_rank_tokens=hot)))
        rows.append([alpha, f"{np.mean(shares)*100:.1f}",
                     f"{np.mean(hots):.0f}", f"{T*K/EP:.0f}",
                     f"{np.mean(lats)*1e6:.1f}"])
        print(f"  alpha={alpha:4.2f}: top-20% experts hold "
              f"{np.mean(shares)*100:5.1f}% of tokens; hottest EP rank "
              f"{np.mean(hots):6.0f} vs balanced {T*K/EP:.0f} "
              f"-> MoE latency {np.mean(lats)*1e6:7.1f} us")
    path = write_csv("fig5_powerlaw.csv",
                     ["alpha", "top20pct_token_share_pct",
                      "hot_rank_tokens", "balanced_rank_tokens",
                      "moe_latency_us"], rows)
    return finalize_result({"csv": path})


if __name__ == "__main__":
    bench_main(run)
