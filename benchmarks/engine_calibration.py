"""Backend-constant calibration — measures the repro-jax engine's real
per-iteration host overhead on this machine (the quantity the
BackendProfile.step_overhead constant models) by timing decode iterations
of a reduced model and subtracting the jit-compute portion."""
from __future__ import annotations

import statistics
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import write_csv
from repro import models
from repro.configs import get_config
from repro.serving.engine import Engine, EngineConfig
from repro.serving.request import Request


def run(quick: bool = False):
    cfg = get_config("internlm2-1.8b").reduced()
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, EngineConfig(max_batch=4, max_seq=96))
    rng = np.random.default_rng(0)
    osl = 16 if quick else 48
    for i in range(4):
        prompt = rng.integers(0, cfg.vocab_size, 8).tolist()
        eng.add_request(Request(rid=i, isl=8, osl=osl,
                                arrival=time.perf_counter(), prompt=prompt))
    # warm the decode jit, then time iterations
    eng.step()
    times = []
    while eng.sched.active:
        t0 = time.perf_counter()
        eng.step()
        times.append(time.perf_counter() - t0)
    # pure-compute comparison: the jitted decode called back-to-back
    tok = jnp.zeros((4, 1), jnp.int32)
    cache = eng.cache
    t0 = time.perf_counter()
    reps = 10
    for _ in range(reps):
        lg, cache = eng._decode_fn(params=eng.params, token=tok, cache=cache)
    lg.block_until_ready()
    compute = (time.perf_counter() - t0) / reps
    step_p50 = statistics.median(times)
    overhead = max(step_p50 - compute, 0.0)
    print(f"  engine iteration p50 {step_p50*1e3:.2f}ms, "
          f"jit compute {compute*1e3:.2f}ms -> host overhead "
          f"{overhead*1e6:.0f}us on THIS CPU container "
          f"(BackendProfile.step_overhead models a TPU-grade host at 120us; "
          f"the structure — fixed per-iteration cost — is what's calibrated)")
    path = write_csv("engine_calibration.csv",
                     ["metric", "seconds"],
                     [["iteration_p50", step_p50],
                      ["jit_compute", compute],
                      ["host_overhead", overhead]])
    return {"csv": path, "overhead_us": overhead * 1e6}


if __name__ == "__main__":
    run()
