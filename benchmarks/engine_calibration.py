"""Backend-constant calibration — measures the repro-jax engine's real
per-iteration host overhead on this machine (the quantity the
BackendProfile.step_overhead constant models) through the
``repro.calibrate`` subsystem's host-measurement helpers."""
from __future__ import annotations

import jax

from benchmarks.common import bench_main, finalize_result, write_csv
from repro import models
from repro.calibrate.host import measure_engine_iteration
from repro.configs import get_config
from repro.serving.engine import Engine, EngineConfig


def run(quick: bool = False):
    cfg = get_config("internlm2-1.8b").reduced()
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, EngineConfig(max_batch=4, max_seq=96))
    m = measure_engine_iteration(eng, cfg, osl=16 if quick else 48,
                                 n_requests=4)
    print(f"  engine iteration p50 {m['iteration_p50']*1e3:.2f}ms, "
          f"jit compute {m['jit_compute']*1e3:.2f}ms -> host overhead "
          f"{m['host_overhead']*1e6:.0f}us on THIS CPU container "
          f"(BackendProfile.step_overhead models a TPU-grade host at 120us; "
          f"the structure — fixed per-iteration cost — is what's calibrated)")
    path = write_csv("engine_calibration.csv",
                     ["metric", "seconds"],
                     [["iteration_p50", m["iteration_p50"]],
                      ["jit_compute", m["jit_compute"]],
                      ["host_overhead", m["host_overhead"]]])
    return finalize_result(
        {"csv": path, "overhead_us": m["host_overhead"] * 1e6})


if __name__ == "__main__":
    bench_main(run)
