"""Goodput vs. burstiness — what the static analytical ranking misses.

Sweeps a ladder of arrival burst factors over one seeded two-tenant
trace shape and replays the analytical frontier's top candidates
open-loop at each point, recording goodput under a tail-latency SLO,
p99 TTFT, and whether the goodput winner still matches the analytical
winner.  As burstiness grows, queueing pushes the throughput-optimal
config past its SLO first — the re-ranking frequency is the headline
column.

    PYTHONPATH=src python -m benchmarks.workload_goodput [--quick]
"""
from __future__ import annotations

from benchmarks.common import bench_main, finalize_result, write_csv
from repro.api import Configurator
from repro.workloads import (ArrivalSpec, LengthSpec, SLOSpec, TenantSpec,
                             TraceSpec, generate_trace)

BURST_FACTORS = (1.5, 2.0, 4.0, 8.0)
RATES = (2.0, 6.0)
SEED = 11


def _trace(rate: float, burst: float, n: int):
    return generate_trace(TraceSpec(
        n_requests=n,
        arrivals=ArrivalSpec(kind="bursty", rate_rps=rate,
                             burst_factor=burst),
        tenants=(
            TenantSpec(name="chat", weight=0.7, priority=1,
                       lengths=LengthSpec(kind="lognormal", isl=256,
                                          osl=64)),
            TenantSpec(name="batch", weight=0.3,
                       lengths=LengthSpec(kind="lognormal", isl=512,
                                          osl=128)),
        )), seed=SEED)


def run(quick: bool = False):
    bursts = BURST_FACTORS[:2] if quick else BURST_FACTORS
    rates = RATES[:1] if quick else RATES
    n = 40 if quick else 80
    slo = SLOSpec(ttft_p99_ms=1500, tpot_p99_ms=60)

    cfg = (Configurator.for_model("llama3.1-8b")
           .traffic(isl=256, osl=64)
           .sla(ttft_ms=2000, min_tokens_per_s_user=10)
           .cluster(chips=8, platform="tpu_v5e")
           .dtype("fp8")
           .modes("aggregated"))
    base_report = cfg.search(generate_launch=False)

    rows = []
    n_reranked = 0
    for rate in rates:
        for burst in bursts:
            trace = _trace(rate, burst, n)
            report = cfg.evaluate_frontier(trace, slo, top_k=3,
                                           report=base_report)
            we = report.workload_eval
            by_index = {c["index"]: c for c in we["candidates"]}
            winner = by_index[we["ranking"][0]]
            r = winner["replay"]
            n_reranked += bool(we["reranked"])
            rows.append([rate, burst, trace.digest(),
                         winner["describe"],
                         int(we["reranked"]),
                         f"{r['goodput_tok_s']:.1f}",
                         f"{100 * r['slo_attainment']:.1f}",
                         f"{r['ttft_ms']['p99']:.1f}",
                         f"{r['queue_depth_max']}"])
            print(f"  rate {rate:4.1f} burst {burst:4.1f}: winner "
                  f"{winner['describe']:14s} goodput "
                  f"{r['goodput_tok_s']:8.1f} tok/s  p99 TTFT "
                  f"{r['ttft_ms']['p99']:7.1f}ms  "
                  f"{'RERANKED' if we['reranked'] else 'same order'}")

    path = write_csv(
        "workload_goodput.csv",
        ["rate_rps", "burst_factor", "trace_digest", "goodput_winner",
         "reranked", "goodput_tok_s", "slo_attainment_pct",
         "p99_ttft_ms", "queue_depth_max"], rows)
    print(f"  {n_reranked}/{len(rows)} points re-ranked the frontier")
    return finalize_result(
        {"csv": path, "n_reranked": n_reranked, "n_points": len(rows)})


if __name__ == "__main__":
    bench_main(run)
