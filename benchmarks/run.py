"""Benchmark harness — one entry per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # full
    PYTHONPATH=src python -m benchmarks.run --quick    # CI-sized

Prints one ``name,us_per_call,derived`` CSV line per benchmark, writes
detailed CSVs under results/, and emits one versioned
:class:`repro.obs.bench.BenchArtifact` per suite run (``--out``) with
per-bench repeat timings, work-counter snapshots, and tracer-span phase
breakdowns — the file ``obs bench compare|gate|trend`` consume.  Runs
are appended to the ``results/bench_history.jsonl`` trajectory unless
``--history ''`` disables it.

The harness assumes ``repro`` is importable (run with ``PYTHONPATH=src``
from the repo root, matching pyproject's ``pythonpath``); there is no
import-time sys.path patching.
"""
from __future__ import annotations

import argparse
import os
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from benchmarks import (ablation_sol, autoscale_diurnal, capacity_ladder,
                        cpu_silicon_fidelity, engine_calibration, fig1_pareto,
                        fig5_powerlaw, fig6_fidelity, fig7_disagg_fidelity,
                        roofline, spec_decode, table1_search_efficiency,
                        table2_case_study, workload_goodput)
from benchmarks.common import RESULTS_DIR, bench_environment

Bench = Tuple[str, Callable[..., Optional[Dict]], Callable[[Dict], str]]

BENCHES: List[Bench] = [
    ("table1_search_efficiency", table1_search_efficiency.run,
     lambda r: f"median_ms_per_config={r.get('per_config_ms', 0):.2f}"),
    ("fig6_aggregated_fidelity", fig6_fidelity.run,
     lambda r: ";".join(f"{s[0]}/{s[1]}:tpot_mape={s[3]}%"
                        for s in r.get("summary", []))),
    ("fig7_disagg_fidelity", fig7_disagg_fidelity.run,
     lambda r: f"thru_mape={r.get('thru_mape', 0):.1f}%"
               f";speed_mape={r.get('speed_mape', 0):.1f}%"),
    ("fig1_pareto_qwen235b", fig1_pareto.run,
     lambda r: f"disagg_gain={r.get('gain_pct', float('nan')):.1f}%"),
    ("table2_case_study", table2_case_study.run,
     lambda r: f"disagg_gain={r.get('gain_pct', float('nan')):.1f}%"),
    ("fig5_powerlaw_alpha", fig5_powerlaw.run, lambda r: "see csv"),
    ("roofline_from_dryrun", roofline.run,
     lambda r: str(r.get("dominants", ""))),
    ("engine_overhead_calibration", engine_calibration.run,
     lambda r: f"overhead_us={r.get('overhead_us', 0):.0f}"),
    ("spec_decode_extension", spec_decode.run,
     lambda r: f"best_speedup={r.get('best_speedup', 0):.2f}x"),
    ("cpu_silicon_fidelity", cpu_silicon_fidelity.run,
     lambda r: f"tpot_mape={r.get('tpot_mape', 0):.1f}%"
               f";ttft_mape={r.get('ttft_mape', 0):.1f}%"),
    ("ablation_calibrated_vs_sol", ablation_sol.run,
     lambda r: f"step_margin={r.get('step_ratio_calibrated', 0):.2f}x"
               f";sol_check={r.get('step_ratio_sol', 0):.2f}x"),
    ("workload_goodput_rerank", workload_goodput.run,
     lambda r: f"reranked={r.get('n_reranked', 0)}"
               f"/{r.get('n_points', 0)}"),
    ("capacity_ladder", capacity_ladder.run,
     lambda r: f"min_chips={r.get('min_chips')}"
               f";n_points={r.get('n_points', 0)}"),
    ("autoscale_diurnal", autoscale_diurnal.run,
     lambda r: f"best_saved_pct={r.get('best_saved_pct')}"
               f";n_points={r.get('n_points', 0)}"),
]


def select_benches(only: str,
                   benches: Optional[Sequence[Bench]] = None) -> List[Bench]:
    """``--only`` filter: comma-separated tokens, substring match each
    (so ``--only capacity`` and ``--only table1,fig1`` both work)."""
    pool = list(BENCHES if benches is None else benches)
    if not only:
        return pool
    tokens = [t.strip() for t in only.split(",") if t.strip()]
    return [b for b in pool if any(t in b[0] for t in tokens)]


def run_suite(quick: bool = False, only: str = "", repeat: int = 1,
              created_at: str = "", benches: Optional[Sequence[Bench]] = None,
              emit=print):
    """Run the (selected) suite and return ``(BenchArtifact, failures)``.

    Each repeat of each benchmark runs under a fresh process-local
    ``MetricsRegistry`` and ``Tracer`` so its work counters and phase
    breakdown are isolated; counters/phases are taken from the first
    repeat (they are deterministic — asserting exactly that is the
    comparator's job), timing stats pool all repeats.
    """
    from repro.obs import (MetricsRegistry, Tracer, disable_metrics,
                           disable_tracing, enable_metrics, enable_tracing)
    from repro.obs.bench import BenchArtifact, BenchRecord, BenchTiming

    selected = select_benches(only, benches)
    emit("name,us_per_call,derived")
    records: List[BenchRecord] = []
    failures = 0
    for name, fn, derive in selected:
        emit(f"# --- {name} ---")
        samples_us: List[float] = []
        counters: Dict[str, float] = {}
        phases: Dict[str, float] = {}
        derived = error = ""
        status = "ok"
        for rep in range(max(1, repeat)):
            registry, tracer = MetricsRegistry(), Tracer()
            enable_metrics(registry)
            enable_tracing(tracer)
            t0 = time.perf_counter()
            try:
                result = fn(quick=quick) or {}
            except Exception as e:  # noqa: BLE001 — keep the harness running
                samples_us.append(1e6 * (time.perf_counter() - t0))
                status, error = "error", f"{type(e).__name__}:{e}"
            finally:
                disable_metrics()
                disable_tracing()
            if status == "error":
                counters = dict(registry.to_dict()["counters"])
                break
            samples_us.append(1e6 * (time.perf_counter() - t0))
            if rep == 0:
                counters = dict(registry.to_dict()["counters"])
                phases = tracer.wall_by_name()
                derived = derive(result)
        timing = BenchTiming.from_samples(samples_us)
        if status == "error":
            failures += 1
            emit(f"{name},{timing.min_us:.0f},ERROR:{error}")
        else:
            emit(f"{name},{timing.median_us:.0f},{derived}")
        records.append(BenchRecord(name=name, status=status, timing=timing,
                                   counters=counters, phases=phases,
                                   derived=derived, error=error))
    artifact = BenchArtifact(suite="quick" if quick else "full",
                             created_at=created_at,
                             environment=bench_environment(),
                             records=records)
    return artifact, failures


def _utc_now() -> str:
    from datetime import datetime, timezone
    return datetime.now(timezone.utc).strftime("%Y-%m-%dT%H:%M:%SZ")


def main(argv: Optional[Sequence[str]] = None) -> int:
    from repro.obs.bench import append_history

    ap = argparse.ArgumentParser(
        description="Run the benchmark suite and emit a BenchArtifact.")
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized variants of every benchmark")
    ap.add_argument("--only", default="",
                    help="comma-separated substrings of benchmark names")
    ap.add_argument("--repeat", type=int, default=1,
                    help="timing repeats per benchmark (min-of-k feeds "
                         "the soft gate)")
    ap.add_argument("--out", default="",
                    help="artifact path (default results/bench_<suite>.json)")
    ap.add_argument("--history",
                    default=os.path.join(RESULTS_DIR, "bench_history.jsonl"),
                    help="append-only run trajectory ('' disables)")
    ap.add_argument("--timestamp", default="",
                    help="created_at override for deterministic artifacts")
    args = ap.parse_args(argv)

    artifact, failures = run_suite(quick=args.quick, only=args.only,
                                   repeat=args.repeat,
                                   created_at=args.timestamp or _utc_now())
    out = args.out or os.path.join(RESULTS_DIR,
                                   f"bench_{artifact.suite}.json")
    parent = os.path.dirname(out)
    if parent:
        os.makedirs(parent, exist_ok=True)
    artifact.save(out)
    print(f"# artifact {out} digest {artifact.digest()} "
          f"({len(artifact.records)} benches, suite={artifact.suite})")
    if args.history:
        append_history(args.history, artifact)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
