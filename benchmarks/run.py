"""Benchmark harness — one entry per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # full
    PYTHONPATH=src python -m benchmarks.run --quick    # CI-sized

Prints one ``name,us_per_call,derived`` CSV line per benchmark and writes
detailed CSVs under results/.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import (ablation_sol, autoscale_diurnal, capacity_ladder,
                        cpu_silicon_fidelity, engine_calibration, fig1_pareto,
                        fig5_powerlaw, fig6_fidelity, fig7_disagg_fidelity,
                        roofline, spec_decode, table1_search_efficiency,
                        table2_case_study, workload_goodput)

BENCHES = [
    ("table1_search_efficiency", table1_search_efficiency.run,
     lambda r: f"median_ms_per_config={r.get('per_config_ms', 0):.2f}"),
    ("fig6_aggregated_fidelity", fig6_fidelity.run,
     lambda r: ";".join(f"{s[0]}/{s[1]}:tpot_mape={s[3]}%"
                        for s in r.get("summary", []))),
    ("fig7_disagg_fidelity", fig7_disagg_fidelity.run,
     lambda r: f"thru_mape={r.get('thru_mape', 0):.1f}%"
               f";speed_mape={r.get('speed_mape', 0):.1f}%"),
    ("fig1_pareto_qwen235b", fig1_pareto.run,
     lambda r: f"disagg_gain={r.get('gain_pct', float('nan')):.1f}%"),
    ("table2_case_study", table2_case_study.run,
     lambda r: f"disagg_gain={r.get('gain_pct', float('nan')):.1f}%"),
    ("fig5_powerlaw_alpha", fig5_powerlaw.run, lambda r: "see csv"),
    ("roofline_from_dryrun", roofline.run,
     lambda r: str(r.get("dominants", ""))),
    ("engine_overhead_calibration", engine_calibration.run,
     lambda r: f"overhead_us={r.get('overhead_us', 0):.0f}"),
    ("spec_decode_extension", spec_decode.run,
     lambda r: f"best_speedup={r.get('best_speedup', 0):.2f}x"),
    ("cpu_silicon_fidelity", cpu_silicon_fidelity.run,
     lambda r: f"tpot_mape={r.get('tpot_mape', 0):.1f}%"
               f";ttft_mape={r.get('ttft_mape', 0):.1f}%"),
    ("ablation_calibrated_vs_sol", ablation_sol.run,
     lambda r: f"step_margin={r.get('step_ratio_calibrated', 0):.2f}x"
               f";sol_check={r.get('step_ratio_sol', 0):.2f}x"),
    ("workload_goodput_rerank", workload_goodput.run,
     lambda r: f"reranked={r.get('n_reranked', 0)}"
               f"/{r.get('n_points', 0)}"),
    ("capacity_ladder", capacity_ladder.run,
     lambda r: f"min_chips={r.get('min_chips')}"
               f";n_points={r.get('n_points', 0)}"),
    ("autoscale_diurnal", autoscale_diurnal.run,
     lambda r: f"best_saved_pct={r.get('best_saved_pct')}"
               f";n_points={r.get('n_points', 0)}"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default="")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    failures = 0
    for name, fn, derive in BENCHES:
        if args.only and args.only not in name:
            continue
        t0 = time.perf_counter()
        try:
            print(f"# --- {name} ---", flush=True)
            result = fn(quick=args.quick) or {}
            us = 1e6 * (time.perf_counter() - t0)
            print(f"{name},{us:.0f},{derive(result)}", flush=True)
        except Exception as e:  # noqa: BLE001 — keep the harness running
            failures += 1
            us = 1e6 * (time.perf_counter() - t0)
            print(f"{name},{us:.0f},ERROR:{type(e).__name__}:{e}", flush=True)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
