"""Shared benchmark utilities: CSV emission, MAPE, simulator adapters."""
from __future__ import annotations

import csv
import os
import statistics
import time
from typing import Dict, Iterable, List, Optional, Sequence

import numpy as np

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "results")


def write_csv(name: str, header: Sequence[str], rows: Iterable[Sequence]):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name)
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        for r in rows:
            w.writerow(r)
    return path


def mape(pred: Sequence[float], true: Sequence[float]) -> float:
    pairs = [(p, t) for p, t in zip(pred, true) if t > 0]
    if not pairs:
        return float("nan")
    return 100.0 * statistics.mean(abs(p - t) / t for p, t in pairs)


def pearson(a: Sequence[float], b: Sequence[float]) -> float:
    a, b = np.asarray(a, float), np.asarray(b, float)
    if len(a) < 2 or a.std() == 0 or b.std() == 0:
        return float("nan")
    return float(np.corrcoef(a, b)[0, 1])


def sim_latency_fn(session, par, flags):
    """StepSpec -> seconds latency callback for the discrete-event simulator
    (ground truth shares the operator DB; it differs in *scheduling*)."""
    def fn(spec):
        return session.spec_latency_ms(par, spec, flags) / 1e3
    return fn


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.seconds = time.perf_counter() - self.t0
