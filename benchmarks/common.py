"""Shared benchmark utilities: CSV emission, MAPE, simulator adapters,
and the environment fingerprint every result dict is stamped with."""
from __future__ import annotations

import csv
import os
import statistics
import time
from typing import Dict, Iterable, List, Optional, Sequence

import numpy as np

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "results")

_ENV_FINGERPRINT: Optional[Dict] = None


def bench_environment() -> Dict:
    """The suite-wide environment fingerprint (platform, python,
    ``REPRO_*`` pricing knobs, PerfDatabase grid hash), computed once
    per process — wallclock numbers are only comparable within it."""
    global _ENV_FINGERPRINT
    if _ENV_FINGERPRINT is None:
        from repro.obs.bench import environment_fingerprint
        _ENV_FINGERPRINT = environment_fingerprint()
    return _ENV_FINGERPRINT


def finalize_result(result: Optional[Dict]) -> Dict:
    """Stamp a benchmark's result dict with the environment
    fingerprint; every ``run()`` returns through this."""
    out = dict(result or {})
    out.setdefault("environment", bench_environment())
    return out


def bench_main(run_fn) -> None:
    """Uniform ``__main__`` entry for per-table modules: every
    benchmark accepts ``--quick`` the same way."""
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized variant")
    args = ap.parse_args()
    run_fn(quick=args.quick)


def write_csv(name: str, header: Sequence[str], rows: Iterable[Sequence]):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name)
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        for r in rows:
            w.writerow(r)
    return path


def mape(pred: Sequence[float], true: Sequence[float]) -> float:
    pairs = [(p, t) for p, t in zip(pred, true) if t > 0]
    if not pairs:
        return float("nan")
    return 100.0 * statistics.mean(abs(p - t) / t for p, t in pairs)


def pearson(a: Sequence[float], b: Sequence[float]) -> float:
    a, b = np.asarray(a, float), np.asarray(b, float)
    if len(a) < 2 or a.std() == 0 or b.std() == 0:
        return float("nan")
    return float(np.corrcoef(a, b)[0, 1])


def sim_latency_fn(session, par, flags):
    """StepSpec -> seconds latency callback for the discrete-event simulator
    (ground truth shares the operator DB; it differs in *scheduling*)."""
    def fn(spec):
        return session.spec_latency_ms(par, spec, flags) / 1e3
    return fn


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.seconds = time.perf_counter() - self.t0
