"""Ablation — calibrated PerfDatabase vs pure speed-of-light roofline.

The paper's §6 differentiation from Vidur/APEX: "these rely on analytical
roofline models ... AIConfigurator differs through its data-driven
foundation".  What we can and cannot adjudicate without silicon:

1. **SoL consistency check** — our SoL fallback agrees with the
   roofline of the compiled dry-run artifacts to ~1% (it must: both are
   max(flops/peak, bytes/bw) over the same program).  The calibrated
   estimates sit a median 1.5-1.6x ABOVE that floor: that margin (launch
   overheads, sub-peak utilization, efficiency curves) is precisely the
   quantity only real profiling can validate — i.e. the stake of the
   paper's data-driven claim, quantified.  The real-silicon benchmark
   (cpu_silicon_fidelity) independently finds measured wall-clock sits
   1.5-2x above SoL-grade estimates, consistent with the margin.

2. **End-to-end TPOT vs the step-accurate simulator** — the simulator
   runs on the calibrated DB, so this slice isolates Algorithm 2's
   *scheduling* error in aggressive regimes (large concurrency); SoL's
   systematic optimism can even cancel scheduling pessimism here, which
   is why per-operator fidelity and scheduling fidelity must be measured
   separately (as the paper does: Fig. 6 per-request metrics vs Table 1
   per-step database).
"""
from __future__ import annotations

import json
import os
import statistics

from benchmarks.common import (bench_main, finalize_result, mape,
                               sim_latency_fn, write_csv)
from repro.core import ClusterSpec, PerfDatabase, SLA, WorkloadDescriptor
from repro.core.config import CandidateConfig, ParallelismConfig, RuntimeFlags
from repro.core.session import InferenceSession
from repro.serving.scheduler import SchedulerConfig
from repro.serving.sim import ServingSimulator, StepSpec

DRYRUN = os.environ.get("REPRO_DRYRUN", "results/dryrun.jsonl")

DECODE_ARCHS = ["qwen3-14b", "qwen2-7b", "internlm2-1.8b",
                "qwen3-moe-30b-a3b", "mixtral-8x22b", "h2o-danube-3-4b"]


def _hlo_floor_ms(rec) -> float:
    PEAK, HBM, ICI = 197e12, 819e9, 100e9
    from benchmarks.roofline import operator_bytes_per_chip
    t_c = rec["flops_corrected"] / PEAK
    t_m = operator_bytes_per_chip(rec["arch"], rec["shape"], rec["mesh"]) / HBM
    return 1e3 * max(t_c, t_m)


def run(quick: bool = False):
    rows = []
    out = {}
    # ---- part 1: per-step vs compiled HLO floor ------------------------
    if os.path.exists(DRYRUN):
        recs = {}
        for line in open(DRYRUN):
            r = json.loads(line)
            recs[(r["arch"], r["shape"], r["mesh"])] = r
        db_cal = PerfDatabase("tpu_v5e", "repro-jax")
        db_sol = PerfDatabase("tpu_v5e", "repro-jax", use_grid=False)
        ratios_cal, ratios_sol = [], []
        for arch in (DECODE_ARCHS[:2] if quick else DECODE_ARCHS):
            rec = recs.get((arch, "decode_32k", "16x16"))
            if not rec or not rec.get("ok"):
                continue
            w = WorkloadDescriptor(
                model=arch, isl=32768, osl=1, sla=SLA(ttft_ms=1e9),
                cluster=ClusterSpec(n_chips=16), backend="repro-jax",
                dtype="bf16")
            par = ParallelismConfig(tp=16)
            flags = RuntimeFlags()
            spec = StepSpec(prefill=(), decode=(32768,) * 8)  # per-chip rows
            t_cal = InferenceSession(w, db_cal).spec_latency_ms(par, spec,
                                                                flags)
            t_sol = InferenceSession(w, db_sol).spec_latency_ms(par, spec,
                                                                flags)
            floor = _hlo_floor_ms(rec)
            ratios_cal.append(t_cal / floor)
            ratios_sol.append(t_sol / floor)
            rows.append(["step_vs_hlo", arch, f"{t_cal:.2f}", f"{t_sol:.2f}",
                         f"{floor:.2f}"])
        med_cal = statistics.median(ratios_cal)
        med_sol = statistics.median(ratios_sol)
        out.update(step_ratio_calibrated=med_cal, step_ratio_sol=med_sol)
        print(f"  per-step estimate / compiled-artifact roofline floor "
              f"(median over {len(ratios_cal)} decode archs):")
        print(f"    pure SoL {med_sol:.2f}x (consistency check: ~1.0 by "
              f"construction)")
        print(f"    calibrated {med_cal:.2f}x — the margin above the floor "
              f"is the efficiency/overhead model, the exact quantity the "
              f"paper's silicon profiling exists to pin down")

    # ---- part 2: end-to-end TPOT vs simulator --------------------------
    db_cal = PerfDatabase("tpu_v5e", "repro-jax")
    db_sol = PerfDatabase("tpu_v5e", "repro-jax", use_grid=False)
    preds = {"calibrated": [], "sol": []}
    trues = []
    for isl, osl, conc in ([(512, 128, 16)] if quick
                           else [(512, 128, 16), (2048, 128, 64),
                                 (4096, 512, 32)]):
        w = WorkloadDescriptor(model="qwen3-32b", isl=isl, osl=osl,
                               sla=SLA(ttft_ms=1e9),
                               cluster=ClusterSpec(n_chips=8),
                               backend="repro-jax", dtype="fp8")
        par = ParallelismConfig(tp=8)
        flags = RuntimeFlags()
        cand = CandidateConfig(parallel=par, batch_size=conc, flags=flags)
        s_cal = InferenceSession(w, db_cal)
        p_cal = s_cal.evaluate_aggregated(cand)
        p_sol = InferenceSession(w, db_sol).evaluate_aggregated(cand)
        if p_cal is None or p_sol is None:
            continue
        sim = ServingSimulator(
            SchedulerConfig(max_batch=conc,
                            max_num_tokens=flags.max_num_tokens),
            sim_latency_fn(s_cal, par, flags))
        m = sim.run(isl=isl, osl=osl, concurrency=conc,
                    max_requests=max(12, conc), warmup=4)
        preds["calibrated"].append(p_cal.tpot_ms)
        preds["sol"].append(p_sol.tpot_ms)
        trues.append(m.tpot_ms)
        rows.append(["tpot_vs_sim", f"{isl}/{osl}/{conc}",
                     f"{p_cal.tpot_ms:.3f}", f"{p_sol.tpot_ms:.3f}",
                     f"{m.tpot_ms:.3f}"])
    out.update(calibrated_mape=mape(preds["calibrated"], trues),
               sol_mape=mape(preds["sol"], trues))
    print(f"  end-to-end TPOT MAPE vs simulator (scheduling-error slice, "
          f"aggressive regimes): calibrated {out['calibrated_mape']:.1f}% / "
          f"SoL {out['sol_mape']:.1f}% — SoL's optimism partially cancels "
          f"Alg-2 pessimism here; per-operator and scheduling fidelity must "
          f"be validated separately")
    out["csv"] = write_csv("ablation_sol.csv",
                           ["part", "case", "calibrated", "sol", "reference"],
                           rows)
    return finalize_result(out)


if __name__ == "__main__":
    bench_main(run)
