"""Quickstart: find the optimal serving configuration for a workload.

    PYTHONPATH=src python examples/quickstart.py

Describes a production workload (model, traffic shape, SLA, cluster),
searches the configuration space in under a second on CPU, prints the
Pareto frontier, and emits a ready-to-run launch command.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (ClusterSpec, PerfDatabase, SLA, TaskRunner,
                        WorkloadDescriptor, generate)


def main():
    workload = WorkloadDescriptor(
        model="qwen3-32b",            # any id from repro.configs
        isl=4000, osl=500,            # traffic shape
        sla=SLA(ttft_ms=1200, min_tokens_per_s_user=60),
        cluster=ClusterSpec(n_chips=16, platform="tpu_v5e"),
        backend="repro-jax",          # or: trtllm | vllm | sglang
        dtype="fp8",
    )

    db = PerfDatabase(workload.cluster.platform, workload.backend)
    result = TaskRunner(workload, db).run()

    print(result.summary())
    print("\nPareto frontier (speed vs per-chip throughput):")
    for p in result.frontier[:10]:
        print(f"  [{p.mode:13s}] {p.tokens_per_s_user:7.1f} tok/s/user  "
              f"{p.tokens_per_s_per_chip:8.1f} tok/s/chip  "
              f"TTFT {p.ttft_ms:7.1f}ms  {p.config.get('describe', '')}")

    launch = generate(workload, result.best)
    print(f"\nlaunch command:\n  {launch.command}")
    out = os.path.join("results", "quickstart_launch.json")
    os.makedirs("results", exist_ok=True)
    with open(out, "w") as f:
        f.write(launch.to_json())
    print(f"launch config -> {out}")


if __name__ == "__main__":
    main()
