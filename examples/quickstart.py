"""Quickstart: find the optimal serving configuration for a workload.

    PYTHONPATH=src python examples/quickstart.py

Describes a production workload fluently (model, traffic shape, SLA,
cluster), searches the configuration space in under a second on CPU,
prints the Pareto frontier, and saves the schema-versioned SearchReport —
launch artifact included — as the machine-readable result.
"""
import os

import _bootstrap  # noqa: F401

from repro.api import Configurator


def main():
    report = (Configurator.for_model("qwen3-32b")   # any id from repro.configs
              .traffic(isl=4000, osl=500)           # traffic shape
              .sla(ttft_ms=1200, min_tokens_per_s_user=60)
              .cluster(chips=16, platform="tpu_v5e")
              .backend("repro-jax")                 # or: trtllm | vllm | sglang
              .dtype("fp8")
              .search())

    print(report.summary())
    print("\nPareto frontier (speed vs per-chip throughput):")
    for p in report.frontier[:10]:
        print(f"  [{p.mode:13s}] {p.tokens_per_s_user:7.1f} tok/s/user  "
              f"{p.tokens_per_s_per_chip:8.1f} tok/s/chip  "
              f"TTFT {p.ttft_ms:7.1f}ms  {p.config.get('describe', '')}")

    print(f"\nlaunch command:\n  {report.launch.command}")
    os.makedirs("results", exist_ok=True)
    out = report.save(os.path.join("results", "quickstart_report.json"))
    print(f"search report (schema v{report.schema_version}) -> {out}")


if __name__ == "__main__":
    main()
