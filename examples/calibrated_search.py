"""Calibration workflow end to end: measure the kernels, fit corrections,
audit the accuracy report, and run a search through the calibrated
database — the artifact's identity travels inside the SearchReport.

Run:  PYTHONPATH=src python examples/calibrated_search.py
"""
import _bootstrap  # noqa: F401

import json

from repro.api import Configurator
from repro.calibrate import (DeterministicTimer, accuracy_report,
                             format_accuracy, run_calibration)

# 1. measure + fit (the deterministic timer keeps this demo reproducible;
#    swap WallClockTimer() in on real silicon)
artifact = run_calibration(
    "tpu_v5e", "repro-jax",
    timer=DeterministicTimer("tpu_v5e"),
    created_at="2026-07-28T00:00:00Z",
    points_per_axis=3)
print(format_accuracy(accuracy_report(artifact)))

# 2. persist the versioned artifact (lossless round-trip)
path = artifact.save("calibration.json")
print(f"\nartifact -> {path} (digest {artifact.digest()})")

# 3. search through the calibrated database
report = (Configurator.for_model("qwen3-32b")
          .traffic(isl=4000, osl=500)
          .sla(ttft_ms=1200, min_tokens_per_s_user=40)
          .cluster(chips=16, platform="tpu_v5e")
          .backend("repro-jax")
          .dtype("fp8")
          .modes("aggregated")
          .with_calibration(artifact)
          .search(generate_launch=False))
print("\n" + report.summary())
print("calibration recorded in the report's database section:")
print(json.dumps(report.fingerprint["calibration"], indent=2))
