"""Streaming search: watch candidates arrive, stop as soon as you're happy.

    PYTHONPATH=src python examples/streaming_search.py

``Configurator.search_iter`` prices candidates lazily and yields a
``SearchEvent`` per projection — the same pricing path batch ``search()``
drains — so an interactive consumer can render progress, watch the online
Pareto frontier grow, and early-exit once enough SLA-valid options exist.
Here ``stop_after_n_valid(5)`` stops the sweep after five valid configs:
every candidate after that is never priced at all.
"""
import _bootstrap  # noqa: F401

from repro.api import Configurator, stop_after_n_valid


def main():
    cfg = (Configurator.for_model("llama3.1-8b")
           .traffic(isl=2000, osl=256)
           .sla(ttft_ms=1500, min_tokens_per_s_user=20)
           .cluster(chips=8, platform="tpu_v5e")
           .dtype("fp8")
           .modes("aggregated"))

    stream = cfg.search_iter(policies=[stop_after_n_valid(5)])
    for ev in stream:
        p = ev.projection
        tick = "+" if ev.meets_sla else " "
        print(f" {tick} #{ev.index:3d}  {p.config.get('describe', ''):14s} "
              f"{p.tokens_per_s_per_chip:8.1f} tok/s/chip  "
              f"{p.tokens_per_s_user:6.1f} tok/s/user  "
              f"frontier={ev.frontier_size}  valid={ev.n_valid}")

    report = stream.report()
    print(f"\n{report.summary()}")
    if report.early_exit:
        print(f"stopped early: {report.early_exit['reason']} after pricing "
              f"{report.early_exit['n_priced']} candidates")
    print(f"database fingerprint: {report.fingerprint['platform']}/"
          f"{report.fingerprint['backend']} "
          f"grids={report.fingerprint['n_grids']} "
          f"hash={report.fingerprint['grid_hash']}")


if __name__ == "__main__":
    main()
