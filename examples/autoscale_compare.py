"""Reactive autoscaling vs the static min-chip plan: can a policy that
rides the diurnal curve spend fewer chip-seconds while still holding
the SLO?

    PYTHONPATH=src python examples/autoscale_compare.py

A seeded diurnal trace (amplitude 0.9 — deep troughs between crests)
is replayed twice on the same memoized session: once by the static
``plan_min_chips`` deployment sized for the whole trace, once by the
``target_queue_depth`` autoscaler, which starts at the static size and
drains replicas through the troughs.  This script asserts the
acceptance property end to end: the autoscaled run spends strictly
fewer chip-seconds than the static plan while holding the attainment
target, and the schema-v5 report round-trips.
"""
import _bootstrap  # noqa: F401

from repro.api import Configurator, SearchReport
from repro.autoscale import TargetQueueDepth
from repro.workloads import (ArrivalSpec, LengthSpec, SLOSpec, TenantSpec,
                             TraceSpec, generate_trace)


def main():
    spec = TraceSpec(
        n_requests=7200,
        arrivals=ArrivalSpec(kind="diurnal", rate_rps=60.0, period_s=60.0,
                             amplitude=0.9),
        tenants=(TenantSpec(lengths=LengthSpec(kind="lognormal", isl=256,
                                               osl=64)),))
    trace = generate_trace(spec, seed=11)
    slo = SLOSpec(ttft_p99_ms=1500, tpot_p99_ms=100)
    print(f"trace: {trace.n_requests} requests over {trace.duration_s:.1f}s "
          f"(diurnal, amplitude 0.9, digest {trace.digest()}); SLO p99 "
          f"TTFT {slo.ttft_p99_ms:.0f}ms, p99 TPOT {slo.tpot_p99_ms:.0f}ms")

    cfg = (Configurator.for_model("llama3.1-8b")
           .traffic(isl=256, osl=64)
           .sla(ttft_ms=2000, min_tokens_per_s_user=10)
           .cluster(chips=8, platform="tpu_v5e")
           .dtype("fp8")
           .modes("aggregated"))

    report = cfg.autoscale(
        trace, slo,
        policy=TargetQueueDepth(target_depth=12.0, max_replicas=2,
                                up_cooldown_s=2.0, down_cooldown_s=8.0,
                                window_s=5.0),
        ladder=(1, 2, 4), tick_s=1.0, cold_start_s=2.0)
    a = report.autoscale

    static = a["static"]
    assert static is not None, "expected the static ladder to attain"
    print(f"\nstatic plan: {static['deployment']['describe']} = "
          f"{static['total_chips']} chips for the whole trace -> "
          f"{static['chip_seconds']:.1f} chip-s at "
          f"{100 * static['slo_attainment']:.1f}% attainment")

    run = a["run"]
    print(f"autoscaled [{run['policy']['name']}]: starts at "
          f"{run['initial_replicas']} replicas, "
          f"{run['n_scale_ups']} up / {run['n_scale_downs']} down "
          f"(peak {run['peak_replicas']}, mean "
          f"{run['mean_replicas']:.2f}) -> {run['chip_seconds']:.1f} "
          f"chip-s at "
          f"{100 * run['metrics']['slo_attainment']:.1f}% attainment")
    for ev in run["events"]:
        if ev["action"] != "retire":
            print(f"    t={ev['t_s']:6.1f}s {ev['action']:>10s} "
                  f"{ev['from']}->{ev['to']}  ({ev['reason']})")

    # the acceptance property: strictly cheaper AND still attaining
    savings = a["savings"]
    assert savings["chip_seconds"] > 0, \
        "expected the autoscaler to spend strictly fewer chip-seconds"
    assert savings["holds_attainment"], \
        "expected the autoscaled run to hold the attainment target"
    assert run["metrics"]["slo_attainment"] >= a["attain_target"]
    print(f"\nsavings: {savings['chip_seconds']:.1f} chip-s "
          f"({savings['chip_seconds_pct']:.1f}%) — holds the "
          f"{100 * a['attain_target']:.0f}% attainment target")

    back = SearchReport.from_json(report.to_json())
    assert back == report and back.autoscale == a
    print("schema-v5 report round-trips losslessly")


if __name__ == "__main__":
    main()
