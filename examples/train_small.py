"""Train a reduced model end-to-end for a few hundred steps on CPU.

    PYTHONPATH=src python examples/train_small.py --arch xlstm-350m --steps 200

Uses the same train_step the production dry-run lowers on the 512-chip
mesh — synthetic data pipeline, AdamW with warmup+cosine, checkpointing.
"""
import argparse
import sys

import _bootstrap  # noqa: F401

from repro.launch import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()
    sys.argv = ["train", "--arch", args.arch, "--steps", str(args.steps),
                "--batch", "8", "--seq", "64", "--lr", "1e-3",
                "--checkpoint", "results/example_ckpt.npz"]
    train.main()


if __name__ == "__main__":
    main()
