"""Aggregated vs disaggregated across workload shapes (Fig. 1-style sweep).

    PYTHONPATH=src python examples/agg_vs_disagg_sweep.py

Shows the paper's §2.2 point: disaggregation is NOT universally superior —
the winner flips with ISL/OSL mix and generation-speed targets.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (ClusterSpec, PerfDatabase, SLA, TaskRunner,
                        WorkloadDescriptor)

SHAPES = [
    (4000, 200, 60),     # prefill-heavy chat, strict speed
    (4000, 2000, 20),    # long generations, relaxed speed
    (512, 1024, 30),     # decode-heavy
    (8000, 256, 40),     # document summarization
]


def main():
    db = PerfDatabase("tpu_v5e", "repro-jax")
    print(f"{'ISL':>6} {'OSL':>6} {'speed>=':>8} | "
          f"{'best agg':>12} {'best disagg':>12} {'winner':>14}")
    for isl, osl, speed in SHAPES:
        w = WorkloadDescriptor(
            model="qwen3-32b", isl=isl, osl=osl,
            sla=SLA(ttft_ms=1500, min_tokens_per_s_user=speed),
            cluster=ClusterSpec(n_chips=16), backend="repro-jax",
            dtype="fp8")
        res = TaskRunner(w, db).run()
        best = {}
        for mode in ("aggregated", "disaggregated"):
            ok = [p for p in res.projections
                  if p.mode == mode and p.meets(w.sla)]
            best[mode] = max((p.tokens_per_s_per_chip for p in ok),
                             default=float("nan"))
        a, d = best["aggregated"], best["disaggregated"]
        winner = "disaggregated" if d == d and d > a else "aggregated"
        print(f"{isl:>6} {osl:>6} {speed:>8} | {a:>12.1f} {d:>12.1f} "
              f"{winner:>14}")


if __name__ == "__main__":
    main()
