"""Aggregated vs disaggregated across workload shapes (Fig. 1-style sweep).

    PYTHONPATH=src python examples/agg_vs_disagg_sweep.py

Shows the paper's §2.2 point: disaggregation is NOT universally superior —
the winner flips with ISL/OSL mix and generation-speed targets.  One
Configurator runs the whole sweep, sharing its PerfDatabase across
scenarios.
"""
import _bootstrap  # noqa: F401

from repro.api import Configurator

SHAPES = [
    (4000, 200, 60),     # prefill-heavy chat, strict speed
    (4000, 2000, 20),    # long generations, relaxed speed
    (512, 1024, 30),     # decode-heavy
    (8000, 256, 40),     # document summarization
]


def main():
    cfg = (Configurator.for_model("qwen3-32b")
           .traffic(isl=SHAPES[0][0], osl=SHAPES[0][1])
           .sla(ttft_ms=1500, min_tokens_per_s_user=SHAPES[0][2])
           .cluster(chips=16).backend("repro-jax").dtype("fp8"))
    comparison = cfg.compare(
        [{"isl": isl, "osl": osl, "min_tokens_per_s_user": speed}
         for isl, osl, speed in SHAPES])

    print(f"{'ISL':>6} {'OSL':>6} {'speed>=':>8} | "
          f"{'best agg':>12} {'best disagg':>12} {'winner':>14}")
    for (isl, osl, speed), rep in zip(SHAPES, comparison.reports):
        best = {}
        for mode in ("aggregated", "disaggregated"):
            ok = [p for p in rep.projections
                  if p.mode == mode and p.meets(rep.workload.sla)]
            best[mode] = max((p.tokens_per_s_per_chip for p in ok),
                             default=float("nan"))
        a, d = best["aggregated"], best["disaggregated"]
        winner = "disaggregated" if d == d and d > a else "aggregated"
        print(f"{isl:>6} {osl:>6} {speed:>8} | {a:>12.1f} {d:>12.1f} "
              f"{winner:>14}")


if __name__ == "__main__":
    main()
