"""Dynamic-workload evaluation: the analytical ranking is not the
production ranking.

    PYTHONPATH=src python examples/workload_replay.py

A seeded bursty multi-tenant trace is replayed against the analytical
frontier's top candidates through the open-loop discrete-event
simulator, where queueing delay counts into TTFT.  Under the
tail-latency SLO the goodput ordering can differ from the static
tok/s/chip ordering — that difference is exactly what the schema-v3
``workload_eval`` section of the SearchReport records, and this script
asserts it end-to-end (including the v3 JSON round-trip).
"""
import _bootstrap  # noqa: F401

from repro.api import Configurator, SearchReport
from repro.workloads import (ArrivalSpec, LengthSpec, SLOSpec, TenantSpec,
                             TraceSpec, generate_trace)


def main():
    # a bursty two-tenant workload: interactive chat (priority) over a
    # background batch tenant with longer prompts
    spec = TraceSpec(
        n_requests=80,
        arrivals=ArrivalSpec(kind="bursty", rate_rps=6.0, burst_factor=4.0),
        tenants=(
            TenantSpec(name="chat", weight=0.7, priority=1,
                       lengths=LengthSpec(kind="lognormal", isl=256, osl=64)),
            TenantSpec(name="batch", weight=0.3,
                       lengths=LengthSpec(kind="lognormal", isl=512,
                                          osl=128)),
        ))
    trace = generate_trace(spec, seed=3)
    print(f"trace: {trace.n_requests} requests over "
          f"{trace.duration_s:.1f}s, tenants {trace.tenants}, "
          f"digest {trace.digest()}")

    slo = SLOSpec(ttft_p99_ms=1500, tpot_p99_ms=60)
    cfg = (Configurator.for_model("llama3.1-8b")
           .traffic(isl=256, osl=64)
           .sla(ttft_ms=2000, min_tokens_per_s_user=10)
           .cluster(chips=8, platform="tpu_v5e")
           .dtype("fp8")
           .modes("aggregated"))

    report = cfg.evaluate_frontier(trace, slo, top_k=3)
    we = report.workload_eval

    print("\nanalytical (static) order vs goodput-under-SLO order:")
    by_index = {c["index"]: c for c in we["candidates"]}
    for rank, idx in enumerate(we["ranking"]):
        c = by_index[idx]
        r = c["replay"]
        print(f"  goodput #{rank + 1}  {c['describe']:14s} "
              f"{r['goodput_tok_s']:8.1f} tok/s  "
              f"attainment {100 * r['slo_attainment']:5.1f}%  "
              f"p99 TTFT {r['ttft_ms']['p99']:7.1f}ms  "
              f"(analytical #{c['analytical_rank'] + 1})")

    # the headline property: replay re-ranks the frontier
    assert we["reranked"], \
        "expected the goodput ranking to differ from the analytical one"
    print("\nre-ranked: the static winner is not the goodput winner")

    # and the v3 report round-trips with the workload section intact
    back = SearchReport.from_json(report.to_json())
    assert back == report and back.workload_eval == we
    print(f"SearchReport v{report.schema_version} round-trip OK "
          f"(workload_eval preserved)")


if __name__ == "__main__":
    main()
