"""Make ``repro`` importable when examples run from a source checkout.

Examples do ``import _bootstrap  # noqa: F401`` instead of hand-rolling
per-file ``sys.path`` surgery.  If the package is installed
(``pip install -e .``) this is a no-op.
"""
import os
import sys

try:
    import repro  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
