"""Minimum-chip capacity planning: how small a deployment still holds
the SLO through the bursts?

    PYTHONPATH=src python examples/capacity_plan.py

A seeded bursty two-tenant trace is replayed across a ladder of replica
counts (each replica a full engine instance behind a router).  The
planner reports the cheapest deployment whose goodput attains the
tail-latency SLO — and this script asserts the acceptance property end
to end: the min-chip deployment attains while the next-cheaper rung
does not, and the schema-v4 report round-trips.
"""
import _bootstrap  # noqa: F401

from repro.api import Configurator, SearchReport
from repro.workloads import (ArrivalSpec, LengthSpec, SLOSpec, TenantSpec,
                             TraceSpec, generate_trace)


def main():
    spec = TraceSpec(
        n_requests=60,
        arrivals=ArrivalSpec(kind="bursty", rate_rps=60.0, burst_factor=4.0),
        tenants=(
            TenantSpec(name="chat", weight=0.7, priority=1,
                       lengths=LengthSpec(kind="lognormal", isl=256, osl=64)),
            TenantSpec(name="batch", weight=0.3,
                       lengths=LengthSpec(kind="lognormal", isl=512,
                                          osl=96)),
        ))
    trace = generate_trace(spec, seed=7)
    slo = SLOSpec(ttft_p99_ms=400, tpot_p99_ms=50)
    print(f"trace: {trace.n_requests} requests over {trace.duration_s:.1f}s "
          f"(digest {trace.digest()}); SLO p99 TTFT {slo.ttft_p99_ms:.0f}ms, "
          f"p99 TPOT {slo.tpot_p99_ms:.0f}ms")

    cfg = (Configurator.for_model("llama3.1-8b")
           .traffic(isl=256, osl=64)
           .sla(ttft_ms=2000, min_tokens_per_s_user=10)
           .cluster(chips=8, platform="tpu_v5e")
           .dtype("fp8")
           .modes("aggregated"))

    report = cfg.plan_capacity(trace, slo, ladder=(1, 2, 4), top_k=1,
                               routing="least_outstanding")
    cap = report.capacity

    print(f"\nladder {cap['ladder']} (routing {cap['routing']}, target "
          f"{100 * cap['attain_target']:.0f}% attainment):")
    for rec in cap["rungs"]:
        if rec["pruned"]:
            print(f"  {rec['deployment']['describe']:>14s} "
                  f"{rec['total_chips']:3d} chips  pruned: {rec['pruned']}")
            continue
        m = rec["metrics"]
        print(f"  {rec['deployment']['describe']:>14s} "
              f"{rec['total_chips']:3d} chips  goodput "
              f"{m['goodput_tok_s']:8.1f} tok/s  attainment "
              f"{100 * m['slo_attainment']:5.1f}%  p99 TTFT "
              f"{m['ttft_ms']['p99']:7.1f}ms  "
              f"{'ATTAINS' if rec['attains'] else 'misses SLO'}")

    plan = cap["plan"]
    assert plan["attained"], "expected the ladder to contain an attaining rung"
    cheaper = [r for r in cap["rungs"]
               if r["pruned"] is None
               and r["total_chips"] < plan["total_chips"]]
    assert cheaper and all(not r["attains"] for r in cheaper), \
        "expected the next-cheaper rung to miss the SLO"
    print(f"\nmin-chip plan: {plan['deployment']['describe']} = "
          f"{plan['total_chips']} chips "
          f"({100 * plan['slo_attainment']:.1f}% attainment); every "
          f"cheaper rung missed the SLO")

    back = SearchReport.from_json(report.to_json())
    assert back == report and back.capacity == cap
    print("schema-v4 report round-trips losslessly")


if __name__ == "__main__":
    main()
