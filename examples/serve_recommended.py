"""End-to-end driver: configurator recommendation -> REAL serving run.

    PYTHONPATH=src python examples/serve_recommended.py

1. Searches the config space for a small dense model.
2. Generates the repro-jax launch config.
3. Boots the real continuous-batching engine (reduced-scale weights on
   CPU) with the recommended settings and serves a batched synthetic
   workload, reporting measured TTFT/TPOT/throughput next to the
   configurator's projections.
"""
import statistics
import time

import _bootstrap  # noqa: F401

import jax
import numpy as np

from repro import models
from repro.api import Configurator
from repro.configs import get_config
from repro.serving.engine import Engine, EngineConfig
from repro.serving.request import Request


def main():
    report = (Configurator.for_model("internlm2-1.8b")
              .traffic(isl=24, osl=12)
              .sla(ttft_ms=10_000, min_tokens_per_s_user=0.1)
              .cluster(chips=8).backend("repro-jax").dtype("bf16")
              .modes("aggregated")
              .search())
    workload = report.workload
    print("recommended:", report.launch.command)
    proj = report.best

    cfg = get_config(workload.model).reduced()
    params = models.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, EngineConfig(
        max_batch=min(proj.batch_size, 8),
        max_seq=workload.isl + workload.osl + 8))

    rng = np.random.default_rng(0)
    n_requests = 12
    t0 = time.perf_counter()
    for i in range(n_requests):
        prompt = rng.integers(0, cfg.vocab_size, workload.isl).tolist()
        eng.add_request(Request(rid=i, isl=workload.isl, osl=workload.osl,
                                arrival=time.perf_counter(), prompt=prompt))
    done = eng.run_until_drained()
    wall = time.perf_counter() - t0

    tpots = [r.tpot for r in done if r.tpot]
    ttfts = [r.ttft for r in done if r.ttft]
    gen = sum(len(r.out_tokens) for r in done)
    print(f"\nserved {len(done)} requests in {wall:.2f}s "
          f"(reduced model, {jax.default_backend()} backend)")
    print(f"measured : TTFT p50 {1e3*statistics.median(ttfts):8.1f}ms   "
          f"TPOT p50 {1e3*statistics.median(tpots):7.2f}ms   "
          f"{gen/wall:7.1f} tok/s")
    print(f"projected: TTFT     {proj.ttft_ms:8.1f}ms   "
          f"TPOT     {proj.tpot_ms:7.2f}ms   (full model on TPU v5e)")
    print("\n(absolute numbers differ: the projection prices the FULL model "
          "on TPU v5e; the engine runs the reduced model on CPU — the "
          "deployment loop is what this example demonstrates)")


if __name__ == "__main__":
    main()
